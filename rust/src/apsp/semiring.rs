//! Closed-semiring algebra layer — one kernel, four serving objectives.
//!
//! The paper's three-phase blocked schedule never uses any property of
//! `(min, +)` beyond closed-semiring algebra: blocked Floyd-Warshall is
//! matrix "multiplication" over a semiring `(⊕, ⊗)` (the 3D-tensor FW
//! re-derivation in PAPERS.md, arxiv 2310.03983, makes the same point).
//! Swapping the semiring therefore swaps the *objective* without touching
//! the schedule:
//!
//! | instance | ⊕ (combine) | ⊗ (extend) | zero | one | objective |
//! |---|---|---|---|---|---|
//! | [`MinPlus`]   | `min` | `+`   | `+inf` | `0`    | shortest path |
//! | [`MaxMin`]    | `max` | `min` | `0`    | `+inf` | widest path / bottleneck |
//! | [`MinMax`]    | `min` | `max` | `+inf` | `0`    | minimax path |
//! | [`BoolOrAnd`] | `or`  | `and` | `0`    | `1`    | transitive closure |
//!
//! All instances keep `f32` as the carrier (the stack's wire and cache
//! currency); [`BoolOrAnd`] uses the bit-friendly `{0.0, 1.0}` encoding so
//! a closure matrix serializes exactly like a distance matrix.
//!
//! **Laws the solvers rely on** (pinned by the unit tests below):
//!
//! * `combine` is associative, commutative, idempotent, with identity
//!   `ZERO` — relaxation order cannot change the optimum;
//! * `extend` is associative with identity `ONE` and annihilator `ZERO`
//!   (`extend(ZERO, x) = ZERO`) — unreachable legs kill a path, padding
//!   vertices are invisible;
//! * `improves(cand, cur)` is the *strict* accept: true iff
//!   `combine(cand, cur) = cand ≠ cur`.  Strictness is what makes
//!   successor tracking deterministic — an equal-value candidate never
//!   replaces an earlier accept, so every tier replays the same ascending-k
//!   accept sequence and agrees on successors, not just values.
//!
//! **Why `(min, +)` is bitwise-pinned while the others are exact.**
//! `MinPlus::extend` is an f32 *addition*: it rounds, so different
//! association orders give different (all individually correctly-rounded)
//! results, and cross-tier agreement must be pinned bitwise per schedule
//! (see `apsp::kernel` module docs).  The three new instances are
//! *selection-only*: `extend` and `combine` both return one of their
//! operands, so every value a solver can produce is drawn from the finite
//! set of input weights and the optimum is exact — any correct algorithm,
//! in any order, returns identical bits.  That is why the conformance
//! suite compares the new semirings against naive references with `==`
//! and no tolerance.
//!
//! The serving surface speaks [`Objective`]: the wire `"objective"` field,
//! router policy, per-objective cache keys, and the CLI `--objective` flag
//! all dispatch through it, with `Objective::Shortest` the default that
//! leaves every existing client, cache key, and code path untouched.

use super::paths::PathsResult;
use crate::graph::DistMatrix;
use crate::INF;

/// The f32 lane operation a semiring op lowers to in a SIMD kernel.
///
/// Every instance's `⊕`/`⊗` is one of three per-lane f32 primitives, which
/// is what lets `apsp::simd` write each ISA's panel kernel **once** and
/// monomorphize it per semiring: the vector kernels select the intrinsic
/// from [`Semiring::COMBINE_OP`] / [`Semiring::EXTEND_OP`] (a match on a
/// const, folded away after monomorphization).  `Min`/`Max` lower to
/// `MINPS`/`MAXPS`-family instructions whose "return the second operand on
/// ties" quirk is bitwise-invisible on the stack's NaN-free, `-0.0`-free
/// domain — equal floats share one bit pattern — so the lane ops are
/// bitwise-identical to the scalar `f32::min`/`f32::max`/`+` (pinned by
/// `lane_ops_are_bitwise_scalar_ops` below).
///
/// `⊕` is always a selection (`Min` or `Max`, never `Add`): that is what
/// makes the compare-mask successor select in the SIMD succ kernels
/// express the strict [`Semiring::improves`] accept exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneOp {
    /// Lane-wise `f32::min` (x86 `MINPS`, NEON `FMINNM`-free `vminq`).
    Min,
    /// Lane-wise `f32::max`.
    Max,
    /// Lane-wise f32 addition.
    Add,
}

/// A closed semiring over `f32` path values.  Implementations are
/// zero-sized marker types; every solver generic over `S: Semiring`
/// monomorphizes to exactly the operations the specialized `(min, +)`
/// code performed, which is what keeps the bitwise contracts intact.
pub trait Semiring: Copy + Send + Sync + 'static {
    /// Wire/display name of the semiring's objective.
    const NAME: &'static str;
    /// ⊕ identity and ⊗ annihilator: the "no path" value.
    const ZERO: f32;
    /// ⊗ identity: the value of the empty path (the diagonal).
    const ONE: f32;

    /// The lane primitive `combine` lowers to — must be a selection
    /// ([`LaneOp::Min`] or [`LaneOp::Max`]) that is bitwise-equal to
    /// `combine` on the instance's domain.
    const COMBINE_OP: LaneOp;
    /// The lane primitive `extend` lowers to — bitwise-equal to `extend`
    /// on the instance's domain.
    const EXTEND_OP: LaneOp;

    /// ⊕ — fold two path values into the better one.
    fn combine(a: f32, b: f32) -> f32;

    /// ⊗ — concatenate two path legs.
    fn extend(a: f32, b: f32) -> f32;

    /// Whether `a` is the annihilator (the hoisted-guard predicate: an
    /// all-`ZERO` column step can be skipped because `extend` annihilates
    /// and `combine` ignores `ZERO`).
    fn is_zero(a: f32) -> bool;

    /// Strict accept: does `cand` beat `cur` outright?  Must equal
    /// `combine(cand, cur) == cand && cand != cur`; the successor kernels
    /// copy a new successor only on a strict improvement.
    fn improves(cand: f32, cur: f32) -> bool;

    /// Validation hook: is `w` a legal *prepared* cell value for this
    /// semiring (diagonal, edges, and `ZERO` cells alike)?
    fn check_value(w: f32) -> Result<(), String>;
}

/// `(min, +)` — shortest path.  The founding instance: its monomorphized
/// generic kernels are bitwise-identical to the pre-refactor specialized
/// code (same ops, same order), and stay pinned by the conformance suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MinPlus;

impl Semiring for MinPlus {
    const NAME: &'static str = "shortest";
    const ZERO: f32 = INF;
    const ONE: f32 = 0.0;
    const COMBINE_OP: LaneOp = LaneOp::Min;
    const EXTEND_OP: LaneOp = LaneOp::Add;

    #[inline(always)]
    fn combine(a: f32, b: f32) -> f32 {
        a.min(b)
    }

    #[inline(always)]
    fn extend(a: f32, b: f32) -> f32 {
        a + b
    }

    #[inline(always)]
    fn is_zero(a: f32) -> bool {
        // +inf is the only non-finite value in the stack (validate rejects
        // NaN and -inf), so this is exactly the specialized kernels'
        // `!a.is_finite()` guard.
        !a.is_finite()
    }

    #[inline(always)]
    fn improves(cand: f32, cur: f32) -> bool {
        cand < cur
    }

    fn check_value(w: f32) -> Result<(), String> {
        if w.is_nan() {
            return Err("NaN".into());
        }
        if w == f32::NEG_INFINITY {
            return Err("-inf".into());
        }
        if w == 0.0 && w.is_sign_negative() {
            return Err("-0.0".into());
        }
        Ok(())
    }
}

/// `(max, min)` — widest path / bottleneck: the largest minimum edge
/// capacity over any route.  Weights are capacities in `(0, +inf)`;
/// `ZERO = 0` (no capacity), `ONE = +inf` (a vertex can carry anything to
/// itself).  Selection-only, hence exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MaxMin;

impl Semiring for MaxMin {
    const NAME: &'static str = "bottleneck";
    const ZERO: f32 = 0.0;
    const ONE: f32 = INF;
    const COMBINE_OP: LaneOp = LaneOp::Max;
    const EXTEND_OP: LaneOp = LaneOp::Min;

    #[inline(always)]
    fn combine(a: f32, b: f32) -> f32 {
        a.max(b)
    }

    #[inline(always)]
    fn extend(a: f32, b: f32) -> f32 {
        a.min(b)
    }

    #[inline(always)]
    fn is_zero(a: f32) -> bool {
        a == 0.0
    }

    #[inline(always)]
    fn improves(cand: f32, cur: f32) -> bool {
        cand > cur
    }

    fn check_value(w: f32) -> Result<(), String> {
        if w.is_nan() {
            return Err("NaN".into());
        }
        if w < 0.0 {
            return Err(format!("negative capacity {w}"));
        }
        Ok(())
    }
}

/// `(min, max)` — minimax path: the smallest maximum edge weight over any
/// route (the other bottleneck).  Weights must be non-negative so
/// `ONE = 0` is a true `max` identity; `ZERO = +inf` as in `(min, +)`.
/// Selection-only, hence exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MinMax;

impl Semiring for MinMax {
    const NAME: &'static str = "minimax";
    const ZERO: f32 = INF;
    const ONE: f32 = 0.0;
    const COMBINE_OP: LaneOp = LaneOp::Min;
    const EXTEND_OP: LaneOp = LaneOp::Max;

    #[inline(always)]
    fn combine(a: f32, b: f32) -> f32 {
        a.min(b)
    }

    #[inline(always)]
    fn extend(a: f32, b: f32) -> f32 {
        a.max(b)
    }

    #[inline(always)]
    fn is_zero(a: f32) -> bool {
        !a.is_finite()
    }

    #[inline(always)]
    fn improves(cand: f32, cur: f32) -> bool {
        cand < cur
    }

    fn check_value(w: f32) -> Result<(), String> {
        if w.is_nan() {
            return Err("NaN".into());
        }
        if w < 0.0 || (w == 0.0 && w.is_sign_negative()) {
            return Err(format!("negative weight {w}"));
        }
        Ok(())
    }
}

/// `(or, and)` — boolean transitive closure on the bit-friendly
/// `{0.0, 1.0}` carrier (`or = max`, `and = min` restricted to the two
/// values), so reachability matrices flow through the same f32 kernels,
/// cache, and wire codec as distances.  Selection-only, hence exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BoolOrAnd;

impl Semiring for BoolOrAnd {
    const NAME: &'static str = "reachability";
    const ZERO: f32 = 0.0;
    const ONE: f32 = 1.0;
    const COMBINE_OP: LaneOp = LaneOp::Max;
    const EXTEND_OP: LaneOp = LaneOp::Min;

    #[inline(always)]
    fn combine(a: f32, b: f32) -> f32 {
        a.max(b)
    }

    #[inline(always)]
    fn extend(a: f32, b: f32) -> f32 {
        a.min(b)
    }

    #[inline(always)]
    fn is_zero(a: f32) -> bool {
        a == 0.0
    }

    #[inline(always)]
    fn improves(cand: f32, cur: f32) -> bool {
        cand > cur
    }

    fn check_value(w: f32) -> Result<(), String> {
        if w == 0.0 && !w.is_sign_negative() || w == 1.0 {
            Ok(())
        } else {
            Err(format!("not a boolean cell: {w}"))
        }
    }
}

// ------------------------------------------------------------ objectives --

/// A serving objective — the request-level name of a semiring instance.
/// `Shortest` is the wire default and the only objective the device tier,
/// johnson, and the incremental update tier serve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Objective {
    /// `(min, +)` — shortest path (the default; bitwise-pinned f32).
    Shortest,
    /// `(max, min)` — widest path over edge capacities.
    Bottleneck,
    /// `(min, max)` — minimize the largest edge along the route.
    Minimax,
    /// `(or, and)` — boolean transitive closure.
    Reachability,
}

impl Objective {
    /// Every objective, in wire-name order.
    pub const ALL: [Objective; 4] = [
        Objective::Shortest,
        Objective::Bottleneck,
        Objective::Minimax,
        Objective::Reachability,
    ];

    /// Parse a wire/CLI objective name.
    pub fn parse(s: &str) -> Option<Objective> {
        match s {
            "shortest" => Some(Objective::Shortest),
            "bottleneck" => Some(Objective::Bottleneck),
            "minimax" => Some(Objective::Minimax),
            "reachability" => Some(Objective::Reachability),
            _ => None,
        }
    }

    /// Wire/CLI name (round-trips through [`Objective::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Shortest => MinPlus::NAME,
            Objective::Bottleneck => MaxMin::NAME,
            Objective::Minimax => MinMax::NAME,
            Objective::Reachability => BoolOrAnd::NAME,
        }
    }

    /// Cache-key tag.  `Shortest` is 0 so every pre-objective cache key —
    /// including the raw `graph_fingerprint` addressing the update tier
    /// uses — is unchanged; see `coordinator::cache::objective_fingerprint`.
    pub fn tag(&self) -> u64 {
        match self {
            Objective::Shortest => 0,
            Objective::Bottleneck => 1,
            Objective::Minimax => 2,
            Objective::Reachability => 3,
        }
    }

    /// Map a request graph (the stack's input convention: zero diagonal,
    /// `+inf` missing edges, finite edge weights) into this objective's
    /// semiring domain, validating edge weights on the way:
    ///
    /// * `Shortest` — the identity (callers skip it on the hot path);
    /// * `Bottleneck` — edges become capacities (must be `> 0`), missing
    ///   edges `ZERO = 0`, the diagonal `ONE = +inf`;
    /// * `Minimax` — the identity on non-negative-weight graphs (the input
    ///   convention already has `ONE = 0` diagonal, `ZERO = +inf` holes);
    /// * `Reachability` — any finite edge becomes `1.0`, missing edges
    ///   `0.0`, the diagonal `1.0`.
    pub fn prepare(&self, g: &DistMatrix) -> Result<DistMatrix, String> {
        let n = g.n();
        match self {
            Objective::Shortest => {
                g.validate()?;
                Ok(g.clone())
            }
            Objective::Bottleneck => {
                let mut out = DistMatrix::from_vec(n, vec![MaxMin::ZERO; n * n]);
                for i in 0..n {
                    for j in 0..n {
                        let w = g.get(i, j);
                        if i == j {
                            out.set(i, j, MaxMin::ONE);
                        } else if w.is_finite() {
                            if w.is_nan() || w <= 0.0 {
                                return Err(format!(
                                    "bottleneck capacity at ({i}, {j}) must be > 0, got {w}"
                                ));
                            }
                            out.set(i, j, w);
                        }
                    }
                }
                Ok(out)
            }
            Objective::Minimax => {
                for i in 0..n {
                    for j in 0..n {
                        let w = g.get(i, j);
                        if i != j && w.is_finite() {
                            MinMax::check_value(w).map_err(|e| {
                                format!("minimax weight at ({i}, {j}): {e}")
                            })?;
                        }
                    }
                }
                g.validate()?;
                Ok(g.clone())
            }
            Objective::Reachability => {
                let mut out = DistMatrix::from_vec(n, vec![BoolOrAnd::ZERO; n * n]);
                for i in 0..n {
                    for j in 0..n {
                        if i == j || g.get(i, j).is_finite() {
                            out.set(i, j, BoolOrAnd::ONE);
                        }
                    }
                }
                Ok(out)
            }
        }
    }
}

/// Pad a semiring matrix to `m ≥ n` with unreachable vertices (`ZERO`
/// off-diagonal, `ONE` diagonal).  The generic analog of
/// [`DistMatrix::padded`] — and identical to it at [`MinPlus`] — sound for
/// the same reason: `extend(·, ZERO) = ZERO` and `combine(·, ZERO)` is the
/// identity, so no route can use a padded vertex.
pub fn padded_semiring<S: Semiring>(g: &DistMatrix, m: usize) -> DistMatrix {
    let n = g.n();
    assert!(m >= n, "cannot pad {n} down to {m}");
    let mut out = DistMatrix::from_vec(m, vec![S::ZERO; m * m]);
    for i in 0..m {
        out.set(i, i, S::ONE);
    }
    for i in 0..n {
        for j in 0..n {
            out.set(i, j, g.get(i, j));
        }
    }
    out
}

// --------------------------------------------------- objective dispatch --

/// Solve a *prepared* matrix under `objective` with the blocked tier.
/// `Shortest` routes through the exact pre-refactor entry point.
pub fn blocked_solve(objective: Objective, g: &DistMatrix, s: usize) -> DistMatrix {
    match objective {
        Objective::Shortest => super::blocked::solve(g, s),
        Objective::Bottleneck => super::blocked::solve_semiring::<MaxMin>(g, s),
        Objective::Minimax => super::blocked::solve_semiring::<MinMax>(g, s),
        Objective::Reachability => super::blocked::solve_semiring::<BoolOrAnd>(g, s),
    }
}

/// Path-carrying twin of [`blocked_solve`].
pub fn blocked_solve_paths(objective: Objective, g: &DistMatrix, s: usize) -> PathsResult {
    match objective {
        Objective::Shortest => super::blocked::solve_paths(g, s),
        Objective::Bottleneck => super::blocked::solve_paths_semiring::<MaxMin>(g, s),
        Objective::Minimax => super::blocked::solve_paths_semiring::<MinMax>(g, s),
        Objective::Reachability => super::blocked::solve_paths_semiring::<BoolOrAnd>(g, s),
    }
}

/// Naive-order reference solve of a *prepared* matrix — the differential
/// oracle for the selection-only semirings (exact equality; see module
/// docs).
pub fn naive_solve(objective: Objective, g: &DistMatrix) -> DistMatrix {
    match objective {
        Objective::Shortest => super::naive::solve(g),
        Objective::Bottleneck => super::naive::solve_semiring::<MaxMin>(g),
        Objective::Minimax => super::naive::solve_semiring::<MinMax>(g),
        Objective::Reachability => super::naive::solve_semiring::<BoolOrAnd>(g),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn law_values<S: Semiring>(samples: &[f32]) {
        for &a in samples {
            // combine: identity, idempotence
            assert_eq!(S::combine(a, S::ZERO).to_bits(), a.to_bits(), "{}", S::NAME);
            assert_eq!(S::combine(S::ZERO, a).to_bits(), a.to_bits(), "{}", S::NAME);
            assert_eq!(S::combine(a, a).to_bits(), a.to_bits(), "{}", S::NAME);
            // extend: identity, annihilator
            assert_eq!(S::extend(a, S::ONE).to_bits(), a.to_bits(), "{}", S::NAME);
            assert_eq!(S::extend(S::ONE, a).to_bits(), a.to_bits(), "{}", S::NAME);
            assert!(S::is_zero(S::extend(a, S::ZERO)), "{}", S::NAME);
            assert!(S::is_zero(S::extend(S::ZERO, a)), "{}", S::NAME);
            // improves is strict and matches combine
            assert!(!S::improves(a, a), "{} improves must be strict", S::NAME);
            for &b in samples {
                let c = S::combine(a, b);
                assert_eq!(c.to_bits(), S::combine(b, a).to_bits(), "{}", S::NAME);
                if S::improves(a, b) {
                    assert_eq!(c.to_bits(), a.to_bits(), "{}", S::NAME);
                    assert_ne!(a.to_bits(), b.to_bits(), "{}", S::NAME);
                    assert!(!S::improves(b, a), "{}", S::NAME);
                }
                for &d in samples {
                    // associativity of both operations
                    assert_eq!(
                        S::combine(S::combine(a, b), d).to_bits(),
                        S::combine(a, S::combine(b, d)).to_bits(),
                        "{}",
                        S::NAME
                    );
                    assert_eq!(
                        S::extend(S::extend(a, b), d).to_bits(),
                        S::extend(a, S::extend(b, d)).to_bits(),
                        "{} (selection-only extend must associate exactly)",
                        S::NAME
                    );
                }
            }
        }
        assert!(S::is_zero(S::ZERO), "{}", S::NAME);
        assert!(!S::is_zero(S::ONE), "{}", S::NAME);
    }

    /// Scalar model of one SIMD lane: what a `MINPS`/`MAXPS`/`ADDPS` lane
    /// computes on clean (NaN-free, `-0.0`-free) inputs.  The x86 min/max
    /// instructions return the *second* operand on ties; on a domain where
    /// equal floats share one bit pattern that choice is unobservable, so
    /// `if a < b { a } else { b }` is the faithful model.
    fn lane_model(op: LaneOp, a: f32, b: f32) -> f32 {
        match op {
            LaneOp::Min => {
                if a < b {
                    a
                } else {
                    b
                }
            }
            LaneOp::Max => {
                if a > b {
                    a
                } else {
                    b
                }
            }
            LaneOp::Add => a + b,
        }
    }

    fn lane_ops_match<S: Semiring>(samples: &[f32]) {
        assert_ne!(
            S::COMBINE_OP,
            LaneOp::Add,
            "{}: ⊕ must be a selection for the compare-mask succ lanes",
            S::NAME
        );
        for &a in samples {
            for &b in samples {
                assert_eq!(
                    lane_model(S::COMBINE_OP, a, b).to_bits(),
                    S::combine(a, b).to_bits(),
                    "{} combine({a}, {b})",
                    S::NAME
                );
                assert_eq!(
                    lane_model(S::EXTEND_OP, a, b).to_bits(),
                    S::extend(a, b).to_bits(),
                    "{} extend({a}, {b})",
                    S::NAME
                );
            }
        }
    }

    #[test]
    fn lane_ops_are_bitwise_scalar_ops() {
        // the contract the per-ISA kernels in apsp::simd lean on: lowering
        // ⊕/⊗ to lane min/max/add is bitwise-invisible on each instance's
        // domain (incl. ties, ZERO, ONE, and +inf)
        lane_ops_match::<MinPlus>(&[-5.0, -0.5, 0.0, 0.25, 1.0, 1.0, 3.5, 1e9, INF]);
        lane_ops_match::<MaxMin>(&[0.0, 0.25, 1.0, 3.5, 1e9, INF]);
        lane_ops_match::<MinMax>(&[0.0, 0.25, 1.0, 3.5, 1e9, INF]);
        lane_ops_match::<BoolOrAnd>(&[0.0, 1.0]);
    }

    #[test]
    fn maxmin_laws() {
        law_values::<MaxMin>(&[0.0, 0.25, 1.0, 3.5, 1e9, INF]);
    }

    #[test]
    fn minmax_laws() {
        law_values::<MinMax>(&[0.0, 0.25, 1.0, 3.5, 1e9, INF]);
    }

    #[test]
    fn bool_laws() {
        law_values::<BoolOrAnd>(&[0.0, 1.0]);
    }

    #[test]
    fn minplus_ops_match_specialized_shapes() {
        // the (min,+) instance must reproduce the specialized kernels'
        // exact operations: f32 min, f32 add, the !is_finite guard, the
        // strict < accept.  (extend associativity does NOT hold here — f32
        // addition rounds — which is exactly why this instance is pinned
        // bitwise per schedule instead of compared exactly across tiers.)
        for &(a, b) in &[(1.5f32, 2.25f32), (0.0, INF), (INF, 3.0), (-2.0, 5.0)] {
            assert_eq!(MinPlus::combine(a, b).to_bits(), a.min(b).to_bits());
            assert_eq!(MinPlus::extend(a, b).to_bits(), (a + b).to_bits());
        }
        assert!(MinPlus::is_zero(INF));
        assert!(!MinPlus::is_zero(0.0));
        assert!(!MinPlus::is_zero(-3.0));
        assert!(MinPlus::improves(1.0, 2.0));
        assert!(!MinPlus::improves(2.0, 2.0));
        assert_eq!(MinPlus::ZERO, INF);
        assert_eq!(MinPlus::ONE.to_bits(), 0f32.to_bits());
    }

    #[test]
    fn objective_names_round_trip() {
        for obj in Objective::ALL {
            assert_eq!(Objective::parse(obj.name()), Some(obj));
        }
        assert_eq!(Objective::parse("widest"), None);
        assert_eq!(Objective::parse(""), None);
        assert_eq!(Objective::parse("SHORTEST"), None, "names are case-sensitive");
        // tags are distinct and Shortest keeps the pre-objective tag 0
        assert_eq!(Objective::Shortest.tag(), 0);
        let mut tags: Vec<u64> = Objective::ALL.iter().map(Objective::tag).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), Objective::ALL.len());
    }

    #[test]
    fn prepare_shapes_per_objective() {
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 2.5);
        g.set(1, 2, 4.0);

        let b = Objective::Bottleneck.prepare(&g).unwrap();
        assert_eq!(b.get(0, 0), INF, "bottleneck diagonal is ONE = +inf");
        assert_eq!(b.get(0, 1), 2.5);
        assert_eq!(b.get(0, 2), 0.0, "missing edge is ZERO = 0");

        let m = Objective::Minimax.prepare(&g).unwrap();
        assert_eq!(m, g, "minimax prepare is the identity on clean inputs");

        let r = Objective::Reachability.prepare(&g).unwrap();
        assert_eq!(r.get(0, 1), 1.0);
        assert_eq!(r.get(1, 0), 0.0);
        assert_eq!(r.get(2, 2), 1.0);

        let s = Objective::Shortest.prepare(&g).unwrap();
        assert_eq!(s, g);
    }

    #[test]
    fn prepare_rejects_out_of_domain_weights() {
        let mut g = DistMatrix::unconnected(2);
        g.set(0, 1, -1.0);
        assert!(Objective::Bottleneck.prepare(&g).is_err());
        assert!(Objective::Minimax.prepare(&g).is_err());
        // reachability does not care about the weight's value
        assert!(Objective::Reachability.prepare(&g).is_ok());
        // shortest accepts negative edges (no negative cycles is a solver
        // concern, not a domain one)
        assert!(Objective::Shortest.prepare(&g).is_ok());
        let mut zero_cap = DistMatrix::unconnected(2);
        zero_cap.set(0, 1, 0.0);
        assert!(Objective::Bottleneck.prepare(&zero_cap).is_err());
        assert!(Objective::Minimax.prepare(&zero_cap).is_ok());
    }

    #[test]
    fn padded_semiring_matches_distmatrix_padded_at_minplus() {
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 1.25);
        g.set(2, 0, -0.5);
        let a = padded_semiring::<MinPlus>(&g, 8);
        let b = g.padded(8);
        assert_eq!(a, b);
        // and the generic shape holds for a zero-different semiring
        let r = Objective::Reachability.prepare(&g).unwrap();
        let p = padded_semiring::<BoolOrAnd>(&r, 5);
        assert_eq!(p.get(4, 4), 1.0, "padded diagonal is ONE");
        assert_eq!(p.get(0, 4), 0.0, "padded holes are ZERO");
        assert_eq!(p.get(0, 1), 1.0);
    }

    #[test]
    fn check_value_hooks() {
        assert!(MinPlus::check_value(-3.0).is_ok());
        assert!(MinPlus::check_value(f32::NAN).is_err());
        assert!(MinPlus::check_value(-0.0).is_err());
        assert!(MaxMin::check_value(0.0).is_ok(), "ZERO is a legal cell");
        assert!(MaxMin::check_value(-1.0).is_err());
        assert!(MinMax::check_value(INF).is_ok(), "ZERO is a legal cell");
        assert!(MinMax::check_value(-1.0).is_err());
        assert!(BoolOrAnd::check_value(0.0).is_ok());
        assert!(BoolOrAnd::check_value(1.0).is_ok());
        assert!(BoolOrAnd::check_value(0.5).is_err());
        assert!(BoolOrAnd::check_value(-0.0).is_err());
    }
}

//! Cache-blocked Floyd-Warshall on the CPU (paper Fig. 2; Venkataraman
//! et al. [4]) — the algorithmic core the GPU kernels specialize.
//!
//! Per stage `b` (tile size `s`, `n/s` stages):
//! 1. **independent block**: full FW on the diagonal tile (sequential k);
//! 2. **singly dependent blocks**: the i-aligned row panel and j-aligned
//!    column panel, each relaxed against the final diagonal tile
//!    (sequential k — one dependency is in the panel itself);
//! 3. **doubly dependent blocks**: every remaining tile relaxed by a
//!    (min, +) product of its column-panel and row-panel tiles; k is
//!    *innermost* (Fig. 2 line 37) because both dependencies are final —
//!    the same order-freedom the GPU kernel exploits.
//!
//! The phase-3 inner loop is written i-k-j so the innermost loop walks two
//! rows contiguously — the CPU analog of the coalesced accesses §4.3
//! engineers on the GPU.

use super::paths::{self, PathsResult};
use crate::graph::DistMatrix;

/// Blocked FW with tile size `s`. Falls back to the naive solver when
/// `n % s != 0` — which covers every `0 < n < s`, since then `n % s == n`.
pub fn solve(w: &DistMatrix, s: usize) -> DistMatrix {
    let mut out = w.clone();
    solve_in_place(&mut out, s);
    out
}

/// Blocked FW with successor tracking: the same tile schedule as [`solve`],
/// with `succ` updated alongside `dist` in every phase (the shared rule:
/// an improvement via pivot `k` copies `succ[i][k]` into `succ[i][j]`).
///
/// Distances are **bitwise identical** to [`solve`] — every phase performs
/// the same f32 additions in the same order, and the branchy
/// `cand < cur` accept test picks the same value as the distance-only
/// branchless `min` (no NaN by [`DistMatrix::validate`], and FW sums never
/// produce `-0.0`).  Falls back to the reference solver
/// ([`paths::solve`]) for degenerate params, mirroring the naive fallback.
pub fn solve_paths(w: &DistMatrix, s: usize) -> PathsResult {
    let n = w.n();
    if n == 0 {
        return PathsResult::from_parts(w.clone(), Vec::new());
    }
    if s == 0 || n % s != 0 {
        return paths::solve(w);
    }
    let mut dist = w.clone();
    let mut succ = paths::init_succ(w);
    let nb = n / s;
    for b in 0..nb {
        let ks = b * s;
        phase1_diag_succ(&mut dist, &mut succ, ks, s);
        for jb in 0..nb {
            if jb != b {
                phase2_row_tile_succ(&mut dist, &mut succ, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                phase2_col_tile_succ(&mut dist, &mut succ, ks, ib * s, s);
            }
        }
        for ib in 0..nb {
            for jb in 0..nb {
                if ib != b && jb != b {
                    phase3_tile_succ(&mut dist, &mut succ, ks, ib * s, jb * s, s);
                }
            }
        }
    }
    PathsResult::from_parts(dist, succ)
}

/// In-place blocked FW (see module docs).
pub fn solve_in_place(w: &mut DistMatrix, s: usize) {
    let n = w.n();
    if n == 0 {
        return;
    }
    if s == 0 || n % s != 0 {
        super::naive::solve_in_place(w);
        return;
    }
    let nb = n / s;
    for b in 0..nb {
        let ks = b * s;
        phase1_diag(w, ks, s);
        for jb in 0..nb {
            if jb != b {
                phase2_row_tile(w, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                phase2_col_tile(w, ks, ib * s, s);
            }
        }
        for ib in 0..nb {
            for jb in 0..nb {
                if ib != b && jb != b {
                    phase3_tile(w, ks, ib * s, jb * s, s);
                }
            }
        }
    }
}

/// Phase 1: full FW restricted to the diagonal tile at (ks, ks).
pub(crate) fn phase1_diag(w: &mut DistMatrix, ks: usize, s: usize) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            for j in ks..ks + s {
                let cand = wik + data[k * n + j];
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                }
            }
        }
    }
}

/// Phase 2, i-aligned: tile rows ks..ks+s, columns js..js+s.
/// `w[i][j] <- min(w[i][j], diag[i][k] + w[k][j])`, sequential k.
pub(crate) fn phase2_row_tile(w: &mut DistMatrix, ks: usize, js: usize, s: usize) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let dik = data[i * n + k]; // in the (final) diagonal tile
            if !dik.is_finite() {
                continue;
            }
            for j in js..js + s {
                let cand = dik + data[k * n + j];
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                }
            }
        }
    }
}

/// Phase 2, j-aligned: tile rows is..is+s, columns ks..ks+s.
/// `w[i][j] <- min(w[i][j], w[i][k] + diag[k][j])`, sequential k.
pub(crate) fn phase2_col_tile(w: &mut DistMatrix, ks: usize, is: usize, s: usize) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in is..is + s {
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            for j in ks..ks + s {
                let cand = wik + data[k * n + j]; // diag row k
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                }
            }
        }
    }
}

/// Phase 1 with successor tracking (same relaxation order as
/// [`phase1_diag`]; both the pivot column `(i, k)` and the target live in
/// the diagonal tile, so the successor source is `succ[i][k]`).
pub(crate) fn phase1_diag_succ(w: &mut DistMatrix, succ: &mut [usize], ks: usize, s: usize) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            let sik = succ[i * n + k];
            for j in ks..ks + s {
                let cand = wik + data[k * n + j];
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Phase 2, i-aligned, with successor tracking (order of
/// [`phase2_row_tile`]; the pivot column `(i, k)` is in the diagonal tile).
pub(crate) fn phase2_row_tile_succ(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    js: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let dik = data[i * n + k];
            if !dik.is_finite() {
                continue;
            }
            let sik = succ[i * n + k];
            for j in js..js + s {
                let cand = dik + data[k * n + j];
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Phase 2, j-aligned, with successor tracking (order of
/// [`phase2_col_tile`]; the pivot column `(i, k)` is in this panel itself).
pub(crate) fn phase2_col_tile_succ(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    is: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in is..is + s {
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            let sik = succ[i * n + k];
            for j in ks..ks + s {
                let cand = wik + data[k * n + j];
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Phase 3 with successor tracking (order of [`phase3_tile`]; the pivot
/// column `(i, k)` is in the column panel).  Plain indexed writes instead
/// of the split-borrow trick — the accept branch needs the comparison
/// anyway, and the succ write makes the inner loop non-vectorizable
/// regardless.
#[inline]
fn phase3_tile_succ(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    is: usize,
    js: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for i in is..is + s {
        for k in ks..ks + s {
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            let sik = succ[i * n + k];
            for j in js..js + s {
                let cand = wik + data[k * n + j];
                if cand < data[i * n + j] {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Phase 3: doubly-dependent tile at (is, js) relaxed against column-panel
/// tile (is, ks) and row-panel tile (ks, js).  i-k-j order: `wik` is hoisted
/// and both inner-row walks are contiguous.
#[inline]
fn phase3_tile(w: &mut DistMatrix, ks: usize, is: usize, js: usize, s: usize) {
    let n = w.n();
    let data = w.as_mut_slice();
    for i in is..is + s {
        for k in ks..ks + s {
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            let (row_k, row_i) = {
                // rows i and k never alias in phase 3 (ib != b)
                debug_assert_ne!(i, k);
                if i < k {
                    let (lo, hi) = data.split_at_mut(k * n);
                    (&hi[js..js + s], &mut lo[i * n + js..i * n + js + s])
                } else {
                    let (lo, hi) = data.split_at_mut(i * n);
                    (&lo[k * n + js..k * n + js + s], &mut hi[js..js + s])
                }
            };
            // branchless min (vectorizes; see naive.rs)
            for j in 0..s {
                row_i[j] = row_i[j].min(wik + row_k[j]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::naive;
    use crate::graph::{generators, DistMatrix};

    fn assert_matches_naive(g: &DistMatrix, s: usize) {
        let expect = naive::solve(g);
        let got = solve(g, s);
        assert!(
            got.allclose(&expect, 1e-5, 1e-6),
            "blocked(s={s}) diverges from naive by {}",
            got.max_abs_diff(&expect)
        );
    }

    #[test]
    fn matches_naive_across_tiles() {
        let g = generators::erdos_renyi(96, 0.3, 17);
        for s in [8, 16, 32, 48, 96] {
            assert_matches_naive(&g, s);
        }
    }

    #[test]
    fn matches_naive_structured() {
        for g in [
            generators::ring(64),
            generators::grid(8, 3),
            generators::scale_free(64, 2, 5),
            generators::layered_dag(8, 8, 7), // negative weights
        ] {
            assert_matches_naive(&g, 16);
        }
    }

    #[test]
    fn non_multiple_falls_back() {
        let g = generators::erdos_renyi(50, 0.4, 3);
        assert_matches_naive(&g, 32); // 50 % 32 != 0 → naive path
    }

    #[test]
    fn single_tile_equals_naive() {
        let g = generators::erdos_renyi(32, 0.5, 9);
        assert_matches_naive(&g, 32);
    }

    #[test]
    fn tile_boundaries() {
        // n == s: exactly one diagonal tile, the blocked path with nb = 1
        let exact = generators::erdos_renyi(16, 0.5, 23);
        assert_matches_naive(&exact, 16);
        // 0 < n < s: n % s == n != 0, so the fallback guard fires without a
        // separate `n < s` test (the condition this regression test pins)
        let small = generators::erdos_renyi(20, 0.5, 27);
        assert_matches_naive(&small, 32);
        // the fallback runs the naive solver itself: bitwise equality
        let tiny = generators::erdos_renyi(7, 0.8, 31);
        assert_eq!(solve(&tiny, 32), naive::solve(&tiny));
    }

    #[test]
    fn empty_and_tiny() {
        solve(&DistMatrix::unconnected(0), 32);
        let d = solve(&DistMatrix::unconnected(1), 32);
        assert_eq!(d.get(0, 0), 0.0);
    }

    #[test]
    fn dense_complete_graph() {
        let g = generators::erdos_renyi(64, 1.0, 13);
        assert_matches_naive(&g, 16);
    }

    #[test]
    fn paths_distances_bitwise_equal_to_distance_only() {
        // the contract solve_paths documents: same schedule, same floats
        let g = generators::erdos_renyi(96, 0.3, 61);
        for s in [16, 32, 48] {
            assert_eq!(solve_paths(&g, s).dist, solve(&g, s), "s={s}");
        }
        // negative weights exercise the accept branch both ways
        let neg = generators::layered_dag(8, 8, 7);
        assert_eq!(solve_paths(&neg, 16).dist, solve(&neg, 16));
    }

    #[test]
    fn paths_reconstruct_to_reported_distances() {
        let g = generators::erdos_renyi(64, 0.25, 67);
        let r = solve_paths(&g, 16);
        for i in 0..g.n() {
            for j in 0..g.n() {
                let d = r.dist.get(i, j);
                match r.path(i, j) {
                    Some(p) => {
                        assert_eq!(*p.first().unwrap(), i);
                        assert_eq!(*p.last().unwrap(), j);
                        let w = r.path_weight(&g, i, j).expect("valid edge walk");
                        assert!((w - d as f64).abs() < 1e-3, "({i},{j}): {w} vs {d}");
                    }
                    None => assert!(!d.is_finite() || i == j),
                }
            }
        }
    }

    #[test]
    fn paths_degenerate_params_fall_back_to_reference() {
        // n % s != 0 → the reference solver runs; results are identical
        let g = generators::erdos_renyi(50, 0.4, 71);
        let fell_back = solve_paths(&g, 32);
        let reference = crate::apsp::paths::solve(&g);
        assert_eq!(fell_back, reference);
        // empty graph
        let empty = solve_paths(&DistMatrix::unconnected(0), 16);
        assert_eq!(empty.n(), 0);
    }

    #[test]
    fn paths_unreachable_iff_dist_infinite() {
        let g = generators::scale_free(60, 2, 73); // plenty of unreachable pairs
        let r = solve_paths(&g, 20);
        for i in 0..g.n() {
            for j in 0..g.n() {
                if i == j {
                    continue;
                }
                assert_eq!(
                    r.succ_at(i, j) == crate::apsp::paths::NO_PATH,
                    !r.dist.get(i, j).is_finite(),
                    "({i},{j})"
                );
            }
        }
    }
}

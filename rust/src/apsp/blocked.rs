//! Cache-blocked Floyd-Warshall on the CPU (paper Fig. 2; Venkataraman
//! et al. [4]) — the algorithmic core the GPU kernels specialize.
//!
//! Per stage `b` (tile size `s`, `n/s` stages):
//! 1. **independent block**: full FW on the diagonal tile (sequential k);
//! 2. **singly dependent blocks**: the i-aligned row panel and j-aligned
//!    column panel, each relaxed against the final diagonal tile
//!    (sequential k — one dependency is in the panel itself); the inner
//!    j sweep is branchless ([`kernel::relax_row`]);
//! 3. **doubly dependent blocks**: every remaining tile relaxed by a
//!    semiring product of its column-panel and row-panel tiles; both
//!    dependencies are final, so the whole update is a pure ⊕-reduction
//!    and runs through the register-tiled microkernel
//!    ([`kernel::panel`]) — the CPU analog of the paper's multi-stage
//!    kernel.  The column-panel tile is packed once per tile row
//!    ([`kernel::PanelBuf`], the §4.3 coalescing analog), which also
//!    de-aliases it from the in-place destination rows.  Both the panel
//!    kernel and the row sweep dispatch to the runtime-selected SIMD ISA
//!    ([`crate::apsp::simd`]) — bitwise-invisible to this driver.
//!
//! The whole schedule is generic over the [`Semiring`]
//! ([`solve_semiring`], [`solve_paths_semiring`]): nothing above uses any
//! property of `(min, +)` beyond `⊕`/`⊗` algebra.  The public `(min, +)`
//! entry points ([`solve`], [`solve_paths`], [`solve_in_place`]) are the
//! generic drivers monomorphized at
//! [`MinPlus`](crate::apsp::semiring::MinPlus) — the identical f32
//! `min`/`+`/finiteness ops in the identical order as the pre-generic
//! code, which is what keeps their outputs bitwise-pinned (the
//! conformance suite checks this against a frozen scalar oracle).
//!
//! Sizes that are not a tile multiple are **padded to the next multiple
//! and truncated** (the device tier's own trick — padding adds only
//! `ZERO`-connected vertices, so values among real vertices are
//! unchanged), keeping every n on the blocked fast path instead of
//! silently degrading to the O(n³) scalar solver.  The one exception is
//! `n < s`: a single padded tile runs phase 1 alone, which *is* the naive
//! pivot order, so the naive solver is called directly — same bits, none
//! of the padded arithmetic.

use std::time::Instant;

use super::kernel::{self, PanelBuf};
use super::paths::{self, PathsResult};
use super::semiring::{padded_semiring, BoolOrAnd, MaxMin, MinMax, MinPlus, Objective, Semiring};
use crate::graph::DistMatrix;

/// Per-phase wall-clock split of one blocked (or stage-parallel) solve.
///
/// Produced by the profiled solver twins ([`solve_profiled`],
/// [`super::parallel::solve_profiled`]): timing reads happen *between*
/// phases, never inside a relaxation loop, so a profiled solve is
/// bitwise-identical to its unprofiled twin (the tests pin this).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseProfile {
    /// Seconds in phase 1 (diagonal-tile FW) across all stages.
    pub phase1_seconds: f64,
    /// Seconds in phase 2 (row + column panels) across all stages.
    pub phase2_seconds: f64,
    /// Seconds in phase 3 (doubly-dependent tiles, packing included)
    /// across all stages.
    pub phase3_seconds: f64,
    /// Stages (pivot-tile rounds) accounted.
    pub rounds: usize,
}

impl PhaseProfile {
    pub fn total_seconds(&self) -> f64 {
        self.phase1_seconds + self.phase2_seconds + self.phase3_seconds
    }
}

/// Blocked FW with tile size `s`.  `n % s != 0` pads up and truncates
/// (see module docs); `s == 0` degrades to the naive solver.
pub fn solve(w: &DistMatrix, s: usize) -> DistMatrix {
    solve_semiring::<MinPlus>(w, s)
}

/// In-place blocked FW (see module docs).
pub fn solve_in_place(w: &mut DistMatrix, s: usize) {
    solve_in_place_semiring::<MinPlus>(w, s);
}

/// Blocked FW with successor tracking: the same tile schedule as [`solve`],
/// with `succ` updated alongside `dist` in every phase (the shared rule:
/// an improvement via pivot `k` copies `succ[i][k]` into `succ[i][j]`).
///
/// Distances are **bitwise identical** to [`solve`] — every phase performs
/// the same f32 additions in the same order, and the branchy
/// `cand < cur` accept test picks the same value as the distance-only
/// branchless `min` (no NaN by [`DistMatrix::validate`], and FW sums never
/// produce `-0.0`).  Non-multiple sizes pad and truncate exactly like the
/// distance solver (padded vertices are unreachable, so no surviving
/// successor can reference one); `n < s` and `s == 0` run the reference
/// solver ([`paths::solve`]) directly — for a single padded tile that is
/// the identical pivot order, bit for bit.
pub fn solve_paths(w: &DistMatrix, s: usize) -> PathsResult {
    solve_paths_semiring::<MinPlus>(w, s)
}

/// Generic blocked FW over any [`Semiring`] — the driver behind [`solve`].
/// Expects the matrix in the semiring's domain (`S::ONE` diagonal,
/// `S::ZERO` absent edges; `Objective::prepare` produces this).
pub fn solve_semiring<S: Semiring>(w: &DistMatrix, s: usize) -> DistMatrix {
    let mut out = w.clone();
    solve_in_place_semiring::<S>(&mut out, s);
    out
}

/// Generic in-place blocked FW — the driver behind [`solve_in_place`].
pub fn solve_in_place_semiring<S: Semiring>(w: &mut DistMatrix, s: usize) {
    let n = w.n();
    if n == 0 {
        return;
    }
    if s == 0 || (n % s != 0 && n < s) {
        // s == 0 is degenerate; n < s is a single padded tile, i.e. pure
        // phase 1 — the naive pivot order bit for bit, minus the padding
        super::naive::solve_in_place_semiring::<S>(w);
        return;
    }
    if n % s != 0 {
        let padded_n = n.div_ceil(s) * s;
        let mut padded = padded_semiring::<S>(w, padded_n);
        solve_in_place_semiring::<S>(&mut padded, s);
        *w = padded.truncated(n);
        return;
    }
    let nb = n / s;
    let mut pack = PanelBuf::default();
    for b in 0..nb {
        let ks = b * s;
        phase1_diag_semiring::<S>(w, ks, s);
        for jb in 0..nb {
            if jb != b {
                phase2_row_tile_semiring::<S>(w, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                phase2_col_tile_semiring::<S>(w, ks, ib * s, s);
            }
        }
        for ib in 0..nb {
            if ib == b {
                continue;
            }
            let is = ib * s;
            pack.pack_dist(&w.as_slice()[is * n + ks..], n, s, s);
            for jb in 0..nb {
                if jb != b {
                    phase3_tile::<S>(w, &pack, ks, is, jb * s, s);
                }
            }
        }
    }
}

/// [`solve`] with a per-phase timing split — bitwise-identical output
/// (`Instant` reads happen only between phases; no float op moves).
pub fn solve_profiled(w: &DistMatrix, s: usize) -> (DistMatrix, PhaseProfile) {
    solve_profiled_semiring::<MinPlus>(w, s)
}

/// Profiled blocked solve dispatched by serving objective (expects the
/// graph in the objective's domain) — the traced coordinator's CPU arm.
pub fn solve_profiled_objective(
    objective: Objective,
    w: &DistMatrix,
    s: usize,
) -> (DistMatrix, PhaseProfile) {
    match objective {
        Objective::Shortest => solve_profiled_semiring::<MinPlus>(w, s),
        Objective::Bottleneck => solve_profiled_semiring::<MaxMin>(w, s),
        Objective::Minimax => solve_profiled_semiring::<MinMax>(w, s),
        Objective::Reachability => solve_profiled_semiring::<BoolOrAnd>(w, s),
    }
}

/// Generic profiled blocked solve — [`solve_profiled`] for any
/// [`Semiring`].
pub fn solve_profiled_semiring<S: Semiring>(
    w: &DistMatrix,
    s: usize,
) -> (DistMatrix, PhaseProfile) {
    let mut out = w.clone();
    let mut prof = PhaseProfile::default();
    solve_in_place_profiled_semiring::<S>(&mut out, s, &mut prof);
    (out, prof)
}

/// The profiled twin of [`solve_in_place_semiring`]: identical dispatch
/// (naive shortcut, pad/truncate recursion) and identical stage loop, with
/// `Instant` reads between the three phase sections.
fn solve_in_place_profiled_semiring<S: Semiring>(
    w: &mut DistMatrix,
    s: usize,
    prof: &mut PhaseProfile,
) {
    let n = w.n();
    if n == 0 {
        return;
    }
    if s == 0 || (n % s != 0 && n < s) {
        // the naive shortcut *is* pure phase-1 pivot order — account it
        // there so the split still sums to the whole solve
        let t0 = Instant::now();
        super::naive::solve_in_place_semiring::<S>(w);
        prof.phase1_seconds += t0.elapsed().as_secs_f64();
        prof.rounds += 1;
        return;
    }
    if n % s != 0 {
        let padded_n = n.div_ceil(s) * s;
        let mut padded = padded_semiring::<S>(w, padded_n);
        solve_in_place_profiled_semiring::<S>(&mut padded, s, prof);
        *w = padded.truncated(n);
        return;
    }
    let nb = n / s;
    let mut pack = PanelBuf::default();
    for b in 0..nb {
        let ks = b * s;
        let t0 = Instant::now();
        phase1_diag_semiring::<S>(w, ks, s);
        let t1 = Instant::now();
        for jb in 0..nb {
            if jb != b {
                phase2_row_tile_semiring::<S>(w, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                phase2_col_tile_semiring::<S>(w, ks, ib * s, s);
            }
        }
        let t2 = Instant::now();
        for ib in 0..nb {
            if ib == b {
                continue;
            }
            let is = ib * s;
            pack.pack_dist(&w.as_slice()[is * n + ks..], n, s, s);
            for jb in 0..nb {
                if jb != b {
                    phase3_tile::<S>(w, &pack, ks, is, jb * s, s);
                }
            }
        }
        prof.phase1_seconds += (t1 - t0).as_secs_f64();
        prof.phase2_seconds += (t2 - t1).as_secs_f64();
        prof.phase3_seconds += t2.elapsed().as_secs_f64();
        prof.rounds += 1;
    }
}

/// Generic blocked FW with successor tracking — the driver behind
/// [`solve_paths`].  The strict [`Semiring::improves`] accept keeps the
/// successor rule deterministic in every instance.
pub fn solve_paths_semiring<S: Semiring>(w: &DistMatrix, s: usize) -> PathsResult {
    let n = w.n();
    if n == 0 {
        return PathsResult::from_parts(w.clone(), Vec::new());
    }
    if s == 0 || (n % s != 0 && n < s) {
        return paths::solve_semiring::<S>(w);
    }
    if n % s != 0 {
        let padded_n = n.div_ceil(s) * s;
        return solve_paths_semiring::<S>(&padded_semiring::<S>(w, padded_n), s).truncated(n);
    }
    let mut dist = w.clone();
    let mut succ = paths::init_succ_semiring::<S>(w);
    let nb = n / s;
    let mut pack = PanelBuf::default();
    for b in 0..nb {
        let ks = b * s;
        phase1_diag_succ_semiring::<S>(&mut dist, &mut succ, ks, s);
        for jb in 0..nb {
            if jb != b {
                phase2_row_tile_succ_semiring::<S>(&mut dist, &mut succ, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                phase2_col_tile_succ_semiring::<S>(&mut dist, &mut succ, ks, ib * s, s);
            }
        }
        for ib in 0..nb {
            if ib == b {
                continue;
            }
            let is = ib * s;
            // the column-panel tile (ib, b) is read-only for the rest of
            // the stage (phase 3 never writes column block b), so one pack
            // serves every jb
            pack.pack_dist(&dist.as_slice()[is * n + ks..], n, s, s);
            pack.pack_succ(&succ[is * n + ks..], n, s, s);
            for jb in 0..nb {
                if jb != b {
                    phase3_tile_succ::<S>(&mut dist, &mut succ, &pack, ks, is, jb * s, s);
                }
            }
        }
    }
    PathsResult::from_parts(dist, succ)
}

/// Phase 1: full FW restricted to the diagonal tile at (ks, ks) —
/// [`phase1_diag_semiring`] at `(min, +)`.
pub(crate) fn phase1_diag(w: &mut DistMatrix, ks: usize, s: usize) {
    phase1_diag_semiring::<MinPlus>(w, ks, s);
}

/// Phase 2, i-aligned, at `(min, +)`.
pub(crate) fn phase2_row_tile(w: &mut DistMatrix, ks: usize, js: usize, s: usize) {
    phase2_row_tile_semiring::<MinPlus>(w, ks, js, s);
}

/// Phase 2, j-aligned, at `(min, +)`.
pub(crate) fn phase2_col_tile(w: &mut DistMatrix, ks: usize, is: usize, s: usize) {
    phase2_col_tile_semiring::<MinPlus>(w, ks, is, s);
}

/// Phase 1 with successor tracking, at `(min, +)`.
pub(crate) fn phase1_diag_succ(w: &mut DistMatrix, succ: &mut [usize], ks: usize, s: usize) {
    phase1_diag_succ_semiring::<MinPlus>(w, succ, ks, s);
}

/// Phase 2, i-aligned, with successor tracking, at `(min, +)`.
pub(crate) fn phase2_row_tile_succ(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    js: usize,
    s: usize,
) {
    phase2_row_tile_succ_semiring::<MinPlus>(w, succ, ks, js, s);
}

/// Phase 2, j-aligned, with successor tracking, at `(min, +)`.
pub(crate) fn phase2_col_tile_succ(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    is: usize,
    s: usize,
) {
    phase2_col_tile_succ_semiring::<MinPlus>(w, succ, ks, is, s);
}

/// Phase 1: full FW restricted to the diagonal tile at (ks, ks).
/// Sequential k (self-dependent), branchless j sweep.
pub(crate) fn phase1_diag_semiring<S: Semiring>(w: &mut DistMatrix, ks: usize, s: usize) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let wik = data[i * n + k];
            if S::is_zero(wik) {
                continue;
            }
            let (out, row_k) = kernel::row_pair_mut(data, n, i, k, ks, s);
            kernel::relax_row_semiring::<S>(out, row_k, wik);
        }
    }
}

/// Phase 2, i-aligned: tile rows ks..ks+s, columns js..js+s.
/// `w[i][j] <- w[i][j] ⊕ (diag[i][k] ⊗ w[k][j])`, sequential k.
pub(crate) fn phase2_row_tile_semiring<S: Semiring>(
    w: &mut DistMatrix,
    ks: usize,
    js: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let dik = data[i * n + k]; // in the (final) diagonal tile
            if S::is_zero(dik) {
                continue;
            }
            let (out, row_k) = kernel::row_pair_mut(data, n, i, k, js, s);
            kernel::relax_row_semiring::<S>(out, row_k, dik);
        }
    }
}

/// Phase 2, j-aligned: tile rows is..is+s, columns ks..ks+s.
/// `w[i][j] <- w[i][j] ⊕ (w[i][k] ⊗ diag[k][j])`, sequential k.
pub(crate) fn phase2_col_tile_semiring<S: Semiring>(
    w: &mut DistMatrix,
    ks: usize,
    is: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in is..is + s {
            let wik = data[i * n + k];
            if S::is_zero(wik) {
                continue;
            }
            // i is outside the diagonal block, so i != k always
            let (out, row_k) = kernel::row_pair_mut(data, n, i, k, ks, s);
            kernel::relax_row_semiring::<S>(out, row_k, wik);
        }
    }
}

/// Phase 1 with successor tracking (same relaxation order as
/// [`phase1_diag_semiring`]; both the pivot column `(i, k)` and the target
/// live in the diagonal tile, so the successor source is `succ[i][k]`).
/// The succ write keeps the accept branchy — same values either way.
pub(crate) fn phase1_diag_succ_semiring<S: Semiring>(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let wik = data[i * n + k];
            if S::is_zero(wik) {
                continue;
            }
            let sik = succ[i * n + k];
            for j in ks..ks + s {
                let cand = S::extend(wik, data[k * n + j]);
                if S::improves(cand, data[i * n + j]) {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Phase 2, i-aligned, with successor tracking (order of
/// [`phase2_row_tile_semiring`]; the pivot column `(i, k)` is in the
/// diagonal tile).
pub(crate) fn phase2_row_tile_succ_semiring<S: Semiring>(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    js: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in ks..ks + s {
            if i == k {
                continue;
            }
            let dik = data[i * n + k];
            if S::is_zero(dik) {
                continue;
            }
            let sik = succ[i * n + k];
            for j in js..js + s {
                let cand = S::extend(dik, data[k * n + j]);
                if S::improves(cand, data[i * n + j]) {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Phase 2, j-aligned, with successor tracking (order of
/// [`phase2_col_tile_semiring`]; the pivot column `(i, k)` is in this panel
/// itself).
pub(crate) fn phase2_col_tile_succ_semiring<S: Semiring>(
    w: &mut DistMatrix,
    succ: &mut [usize],
    ks: usize,
    is: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in ks..ks + s {
        for i in is..is + s {
            let wik = data[i * n + k];
            if S::is_zero(wik) {
                continue;
            }
            let sik = succ[i * n + k];
            for j in ks..ks + s {
                let cand = S::extend(wik, data[k * n + j]);
                if S::improves(cand, data[i * n + j]) {
                    data[i * n + j] = cand;
                    succ[i * n + j] = sik;
                }
            }
        }
    }
}

/// Split the matrix into the mutable destination rows (starting at tile
/// row `is`) and the read-only `s × n` row panel (rows ks..ks+s).  Legal
/// because phase-3 tiles never sit on the panel rows (`ib != b`).
fn split_tile_rows(
    data: &mut [f32],
    n: usize,
    s: usize,
    is: usize,
    ks: usize,
) -> (&mut [f32], &[f32]) {
    debug_assert_ne!(is, ks);
    if is < ks {
        let (lo, hi) = data.split_at_mut(ks * n);
        (&mut lo[is * n..], &hi[..s * n])
    } else {
        let (lo, hi) = data.split_at_mut(is * n);
        (&mut hi[..], &lo[ks * n..(ks + s) * n])
    }
}

/// Phase 3 with successor tracking: same microkernel routing as
/// [`phase3_tile`], with the packed column-panel successors as the copy
/// source — distances *and* successors bitwise-match the scalar twin
/// (ascending k, strict accept; see `kernel`'s module docs).
#[inline]
fn phase3_tile_succ<S: Semiring>(
    w: &mut DistMatrix,
    succ: &mut [usize],
    col: &PanelBuf,
    ks: usize,
    is: usize,
    js: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    let (dst, panel) = split_tile_rows(data, n, s, is, ks);
    kernel::panel_succ::<S>(
        &mut dst[js..],
        &mut succ[is * n + js..],
        n,
        col.dist(),
        col.succ(),
        s,
        &panel[js..],
        n,
        s,
        s,
        s,
    );
}

/// Phase 3: doubly-dependent tile at (is, js) relaxed against the packed
/// column-panel tile (is, ks) and the in-place row-panel tile (ks, js),
/// through the register-tiled microkernel.
#[inline]
fn phase3_tile<S: Semiring>(
    w: &mut DistMatrix,
    col: &PanelBuf,
    ks: usize,
    is: usize,
    js: usize,
    s: usize,
) {
    let n = w.n();
    let data = w.as_mut_slice();
    let (dst, panel) = split_tile_rows(data, n, s, is, ks);
    kernel::panel::<S>(&mut dst[js..], n, col.dist(), s, &panel[js..], n, s, s, s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::naive;
    use crate::apsp::semiring::{BoolOrAnd, MaxMin, MinMax, Objective};
    use crate::graph::{generators, DistMatrix};

    fn assert_matches_naive(g: &DistMatrix, s: usize) {
        let expect = naive::solve(g);
        let got = solve(g, s);
        assert!(
            got.allclose(&expect, 1e-5, 1e-6),
            "blocked(s={s}) diverges from naive by {}",
            got.max_abs_diff(&expect)
        );
    }

    #[test]
    fn matches_naive_across_tiles() {
        let g = generators::erdos_renyi(96, 0.3, 17);
        for s in [8, 16, 32, 48, 96] {
            assert_matches_naive(&g, s);
        }
    }

    #[test]
    fn matches_naive_structured() {
        for g in [
            generators::ring(64),
            generators::grid(8, 3),
            generators::scale_free(64, 2, 5),
            generators::layered_dag(8, 8, 7), // negative weights
        ] {
            assert_matches_naive(&g, 16);
        }
    }

    #[test]
    fn non_multiple_pads_and_truncates() {
        let g = generators::erdos_renyi(50, 0.4, 3);
        assert_matches_naive(&g, 32); // 50 % 32 != 0 → padded to 64
        // the pad-and-truncate contract, bitwise: solving the padded graph
        // directly and cutting the corner is exactly what solve() does
        let padded = solve(&g.padded(64), 32).truncated(50);
        assert_eq!(solve(&g, 32), padded);
    }

    #[test]
    fn single_tile_equals_naive() {
        let g = generators::erdos_renyi(32, 0.5, 9);
        assert_matches_naive(&g, 32);
    }

    #[test]
    fn tile_boundaries() {
        // n == s: exactly one diagonal tile, the blocked path with nb = 1
        let exact = generators::erdos_renyi(16, 0.5, 23);
        assert_matches_naive(&exact, 16);
        // 0 < n < s: a single padded tile would run phase 1 alone — the
        // naive pivot order — so the solver calls naive directly; pin the
        // equivalence the shortcut relies on
        let small = generators::erdos_renyi(20, 0.5, 27);
        assert_matches_naive(&small, 32);
        let tiny = generators::erdos_renyi(7, 0.8, 31);
        assert_eq!(solve(&tiny, 32), naive::solve(&tiny));
        // ... which must also be bitwise what the padded path computes
        assert_eq!(solve(&tiny, 32), solve(&tiny.padded(32), 32).truncated(7));
    }

    #[test]
    fn empty_and_tiny() {
        solve(&DistMatrix::unconnected(0), 32);
        let d = solve(&DistMatrix::unconnected(1), 32);
        assert_eq!(d.get(0, 0), 0.0);
    }

    #[test]
    fn dense_complete_graph() {
        let g = generators::erdos_renyi(64, 1.0, 13);
        assert_matches_naive(&g, 16);
    }

    #[test]
    fn paths_distances_bitwise_equal_to_distance_only() {
        // the contract solve_paths documents: same schedule, same floats
        let g = generators::erdos_renyi(96, 0.3, 61);
        for s in [16, 32, 48] {
            assert_eq!(solve_paths(&g, s).dist, solve(&g, s), "s={s}");
        }
        // negative weights exercise the accept branch both ways
        let neg = generators::layered_dag(8, 8, 7);
        assert_eq!(solve_paths(&neg, 16).dist, solve(&neg, 16));
        // padded sizes carry the same contract
        let ragged = generators::erdos_renyi(50, 0.4, 71);
        assert_eq!(solve_paths(&ragged, 32).dist, solve(&ragged, 32));
    }

    #[test]
    fn paths_reconstruct_to_reported_distances() {
        let g = generators::erdos_renyi(64, 0.25, 67);
        let r = solve_paths(&g, 16);
        for i in 0..g.n() {
            for j in 0..g.n() {
                let d = r.dist.get(i, j);
                match r.path(i, j) {
                    Some(p) => {
                        assert_eq!(*p.first().unwrap(), i);
                        assert_eq!(*p.last().unwrap(), j);
                        let w = r.path_weight(&g, i, j).expect("valid edge walk");
                        assert!((w - d as f64).abs() < 1e-3, "({i},{j}): {w} vs {d}");
                    }
                    None => assert!(!d.is_finite() || i == j),
                }
            }
        }
    }

    #[test]
    fn paths_non_multiple_pads_and_truncates() {
        // n % s != 0 now pads instead of degrading to the reference
        // solver: distances match the distance solver bitwise, and the
        // result is exactly the padded solve, truncated
        let g = generators::erdos_renyi(50, 0.4, 71);
        let r = solve_paths(&g, 32);
        assert_eq!(r.dist, solve(&g, 32));
        assert_eq!(r, solve_paths(&g.padded(64), 32).truncated(50));
        // n < s still runs the reference solver (single padded tile ==
        // naive pivot order; skip the padded arithmetic)
        let small = generators::erdos_renyi(20, 0.5, 73);
        assert_eq!(solve_paths(&small, 32), crate::apsp::paths::solve(&small));
        // empty graph
        let empty = solve_paths(&DistMatrix::unconnected(0), 16);
        assert_eq!(empty.n(), 0);
    }

    #[test]
    fn paths_unreachable_iff_dist_infinite() {
        let g = generators::scale_free(60, 2, 73); // plenty of unreachable pairs
        let r = solve_paths(&g, 20);
        for i in 0..g.n() {
            for j in 0..g.n() {
                if i == j {
                    continue;
                }
                assert_eq!(
                    r.succ_at(i, j) == crate::apsp::paths::NO_PATH,
                    !r.dist.get(i, j).is_finite(),
                    "({i},{j})"
                );
            }
        }
    }

    /// Prepared random graph for a given objective (positive weights so
    /// every objective's domain accepts it).
    fn prepared(objective: Objective, n: usize, seed: u64) -> DistMatrix {
        let g = generators::erdos_renyi(n, 0.3, seed);
        objective.prepare(&g).expect("positive-weight graph prepares")
    }

    #[test]
    fn generic_semirings_match_naive_exactly_across_tiles() {
        // selection-only semirings never round: blocked (any tile size,
        // padded or not) must equal the naive generic loop to the bit
        fn check<S: Semiring>(objective: Objective) {
            for (n, seed) in [(48usize, 19u64), (50, 29)] {
                let g = prepared(objective, n, seed);
                let expect = naive::solve_semiring::<S>(&g);
                for s in [8, 16, 32] {
                    let got = solve_semiring::<S>(&g, s);
                    assert_eq!(got, expect, "{} n={n} s={s}", S::NAME);
                }
            }
        }
        check::<MaxMin>(Objective::Bottleneck);
        check::<MinMax>(Objective::Minimax);
        check::<BoolOrAnd>(Objective::Reachability);
    }

    #[test]
    fn generic_paths_distances_match_and_witness_their_value() {
        // values must equal the distance-only solve exactly; successors may
        // legitimately pick a different optimal witness than the naive
        // reference (accept order differs across schedules), so the path
        // check is semantic: walking the reconstructed path through ⊗ must
        // reproduce the reported optimum
        fn check<S: Semiring>(objective: Objective) {
            let g = prepared(objective, 48, 37);
            let r = solve_paths_semiring::<S>(&g, 16);
            assert_eq!(r.dist, solve_semiring::<S>(&g, 16), "{}", S::NAME);
            for i in 0..g.n() {
                for j in 0..g.n() {
                    if i == j {
                        continue;
                    }
                    let v = r.dist.get(i, j);
                    match r.path(i, j) {
                        Some(p) => {
                            assert_eq!(*p.first().unwrap(), i);
                            assert_eq!(*p.last().unwrap(), j);
                            let mut walked = S::ONE;
                            for pair in p.windows(2) {
                                walked = S::extend(walked, g.get(pair[0], pair[1]));
                            }
                            assert_eq!(
                                walked.to_bits(),
                                v.to_bits(),
                                "{} ({i},{j}): path {p:?} walks to {walked}, dist {v}",
                                S::NAME
                            );
                        }
                        None => assert!(S::is_zero(v), "{} ({i},{j})", S::NAME),
                    }
                }
            }
        }
        check::<MaxMin>(Objective::Bottleneck);
        check::<MinMax>(Objective::Minimax);
        check::<BoolOrAnd>(Objective::Reachability);
    }

    #[test]
    fn profiled_solve_is_bitwise_identical() {
        // the observability contract: the profiled twin runs the same
        // schedule with timing reads between phases only
        let g = generators::erdos_renyi(96, 0.3, 53);
        for s in [16, 32] {
            let (dist, prof) = solve_profiled(&g, s);
            assert_eq!(dist, solve(&g, s), "s={s}");
            assert_eq!(prof.rounds, 96 / s);
            assert!(prof.phase1_seconds >= 0.0);
            assert!(prof.total_seconds() > 0.0);
        }
        // ragged n takes the pad/truncate recursion; n < s the naive
        // shortcut (accounted as phase 1); both stay bitwise
        let ragged = generators::erdos_renyi(50, 0.4, 59);
        let (dist, prof) = solve_profiled(&ragged, 32);
        assert_eq!(dist, solve(&ragged, 32));
        assert_eq!(prof.rounds, 2);
        let tiny = generators::erdos_renyi(7, 0.8, 61);
        let (dist, prof) = solve_profiled(&tiny, 32);
        assert_eq!(dist, solve(&tiny, 32));
        assert_eq!(prof.rounds, 1);
        assert_eq!(prof.phase2_seconds, 0.0);
        // and for every semiring instance
        for objective in [
            Objective::Bottleneck,
            Objective::Minimax,
            Objective::Reachability,
        ] {
            let g = prepared(objective, 48, 43);
            let (dist, _) = solve_profiled_objective(objective, &g, 16);
            use crate::apsp::semiring::blocked_solve;
            assert_eq!(dist, blocked_solve(objective, &g, 16), "{objective:?}");
        }
    }

    #[test]
    fn reachability_closure_is_boolean() {
        let g = prepared(Objective::Reachability, 40, 41);
        let d = solve_semiring::<BoolOrAnd>(&g, 16);
        assert!(d.as_slice().iter().all(|&v| v == 0.0 || v == 1.0));
        // diagonal reaches itself
        for i in 0..d.n() {
            assert_eq!(d.get(i, i), 1.0);
        }
    }
}

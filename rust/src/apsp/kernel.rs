//! Register-tiled semiring microkernel — the shared phase-3 engine of
//! every blocked tier.
//!
//! The paper's 5× win comes from a multi-stage kernel in which each thread
//! computes **multiple output cells from registers**, cutting shared-memory
//! traffic until the scheduler can hide what latency remains (§4.2).  This
//! module is the CPU analog: one microkernel computes an `MR × NR` register
//! block of outputs per outer step, so the inner k-walk performs
//! `MR + NR` loads per `MR · NR` semiring updates instead of the
//! `2 · NR` loads *plus `NR` stores per `NR` updates* of the scalar
//! one-row-at-a-time loop it replaces (Rucci et al. report the same
//! transformation carrying the blocked-FW schedule on KNL; PAPERS.md).
//!
//! The kernel family is generic over [`Semiring`] ([`panel`],
//! [`panel_succ`], [`panel_reference`], [`relax_row_semiring`]): blocked
//! Floyd-Warshall only ever needs `⊕`/`⊗` closed-semiring algebra, so one
//! register tiling serves shortest path, bottleneck, minimax, and
//! transitive closure.  The `(min, +)` instance stays the monomorphized,
//! bitwise-pinned specialization: [`minplus_panel`] /
//! [`minplus_panel_succ`] / [`minplus_panel_reference`] / [`relax_row`]
//! are thin wrappers instantiating the generics at
//! [`MinPlus`](crate::apsp::semiring::MinPlus), which performs exactly the
//! f32 `min`/`+`/`!is_finite()`/strict-`<` operations of the pre-generic
//! code — same ops, same order, same bits.
//!
//! Every caller — `apsp::blocked`, `apsp::parallel`,
//! `superblock::minplus` — routes its doubly-dependent (phase-3) updates
//! through the panel kernels, and its phase-1/2 branchless j-sweeps
//! through the row relaxation.  The conformance suite pins the `(min, +)`
//! tiers against each other bitwise, so the rules that make the tiling
//! legal are load-bearing:
//!
//! * **Phase 3 is a pure ⊕-reduction.**  `dst`, `col`, and `row` are
//!   disjoint and final for the duration of the call, so for each output
//!   cell the result is a fold of `⊕` over `k`-indexed candidates
//!   `col[r][k] ⊗ row[k][c]`.  For `(min, +)`: f32 `min` over NaN-free,
//!   `-0.0`-free inputs ([`crate::graph::DistMatrix::validate`] rejects
//!   NaN, `-inf`, *and* `-0.0`, and the coordinator validates every
//!   request; FW sums never create `-0.0` from clean inputs) is
//!   associative and commutative **bitwise**, so register blocking,
//!   write-once accumulation, and the hoisted annihilator guard cannot
//!   perturb a single bit relative to the scalar conditional-store loop.
//!   For the selection-only semirings the same fold is *exact*, so the
//!   guarantee is stronger still.  The kernel tests pin this against a
//!   scalar reference across tile sizes, infinity densities, and ragged
//!   edges.
//! * **Phases 1–2 are not.**  Their `k` loop carries a dependency (row
//!   `k` / column `k` are updated while still in use), so only the inner
//!   `j` sweep may go branchless ([`relax_row`] — value-identical to the
//!   branchy accept because `⊕` picks the same value); reassociating or
//!   blocking `k` there would change `(min, +)` results.  Callers keep
//!   `k` sequential.
//! * **Successor twins replay the same accept sequence.**  The succ
//!   kernel processes `k` in ascending order per cell with the strict
//!   [`Semiring::improves`] accept, which is exactly the scalar order —
//!   so both the values *and* the successor matrix match the scalar twin
//!   bitwise.
//!
//! [`PanelBuf`] packs a strided column panel into a contiguous tile — the
//! coalescing analog of the paper's §4.3 layout transform — which both
//! feeds the microkernel unit-stride `k`-walks and resolves the borrow
//! overlap when the column panel shares rows with `dst` (the in-place and
//! banded tiers).  [`should_pack`] documents when packing pays on its own.
//!
//! As of the SIMD PR, [`panel`], [`panel_succ`], and
//! [`relax_row_semiring`] dispatch to the process-wide lane ISA chosen by
//! [`crate::apsp::simd`] (AVX2/AVX-512/NEON, `FW_KERNEL` override, scalar
//! fallback); [`panel_scalar`] / [`panel_succ_scalar`] are the unchanged
//! PR 4 register-tiled loops, and `*_with` variants take an explicit
//! [`Isa`] so every compiled path can be pinned and priced in one process.

use super::semiring::{MinPlus, Semiring};
use super::simd::{self, Isa};

/// Register-block rows: output cells each microkernel step holds per row
/// group.  4 broadcast values per k-step.
pub const MR: usize = 4;
/// Register-block columns: one 8-wide f32 vector per accumulator row.
pub const NR: usize = 8;

/// Stride (in elements) past which packing a column panel into a
/// contiguous buffer pays for itself even absent borrow aliasing: beyond
/// ~a cache line per row-step the strided k-walk starts missing L1 and
/// costing TLB entries, and the `rows × kk` copy is `1/cols` of the tile's
/// arithmetic.  Drivers whose column panel shares rows with `dst`
/// (in-place and banded phase 3) must pack regardless.
pub const PACK_MIN_STRIDE: usize = 128;

/// Whether packing a `rows × kk` column panel read at `stride` is worth
/// the copy when the caller has a choice (detached tiles are already
/// contiguous, `stride == kk`, and never repack).
#[inline]
pub fn should_pack(stride: usize, kk: usize) -> bool {
    stride >= PACK_MIN_STRIDE && stride > kk
}

/// Branchless semiring row sweep shared by the phase-1/2 bodies:
/// `out[j] = out[j] ⊕ (wik ⊗ row_k[j])`, dispatched to the process-wide
/// kernel ISA ([`simd::active`]).
///
/// For `(min, +)` this is value-identical to the branchy `if cand < out[j]`
/// accept (no NaN, no `-0.0`, and equal floats share one bit pattern), and
/// free of the store branch — so the scalar form autovectorizes and the
/// explicit lane forms compute the same bits per element (the sweep never
/// reassociates across `j`).  Callers must keep `k` sequential — see the
/// module docs for why phases 1–2 admit only this much.
#[inline]
pub fn relax_row_semiring<S: Semiring>(out: &mut [f32], row_k: &[f32], wik: f32) {
    relax_row_with::<S>(simd::active(), out, row_k, wik);
}

/// [`relax_row_semiring`] on an explicit ISA.  An ISA this build does not
/// compile falls back to scalar (same bits per element — there is nothing
/// to observe); hosts should still only pass available ISAs.
#[inline]
pub fn relax_row_with<S: Semiring>(isa: Isa, out: &mut [f32], row_k: &[f32], wik: f32) {
    debug_assert_eq!(out.len(), row_k.len());
    debug_assert!(isa.available(), "kernel ISA {} unavailable on this host", isa.name());
    match isa {
        Isa::Scalar => relax_row_scalar::<S>(out, row_k, wik),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { simd::x86::relax_row_avx2::<S>(out, row_k, wik) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe { simd::x86::relax_row_avx512::<S>(out, row_k, wik) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::arm::relax_row_neon::<S>(out, row_k, wik) },
        #[allow(unreachable_patterns)]
        _ => relax_row_scalar::<S>(out, row_k, wik),
    }
}

/// The scalar row sweep — the PR 4 loop, kept as the fallback lane shape
/// and the oracle the SIMD sweeps are held to.
#[inline(always)]
pub fn relax_row_scalar<S: Semiring>(out: &mut [f32], row_k: &[f32], wik: f32) {
    let len = out.len().min(row_k.len());
    for j in 0..len {
        out[j] = S::combine(out[j], S::extend(wik, row_k[j]));
    }
}

/// `(min, +)` row sweep: `out[j] = min(out[j], wik + row_k[j])` — the
/// monomorphized specialization every pre-generic caller used.
#[inline(always)]
pub fn relax_row(out: &mut [f32], row_k: &[f32], wik: f32) {
    relax_row_semiring::<MinPlus>(out, row_k, wik);
}

/// Disjoint `(&mut row_i[j0..j0+len], &row_k[j0..j0+len])` views of two
/// distinct rows of a row-major `… × n` matrix — the split-borrow that
/// lets the in-place phase-1/2 sweeps run branchless without indexing
/// through the full buffer on every element.
#[inline]
pub fn row_pair_mut(
    data: &mut [f32],
    n: usize,
    i: usize,
    k: usize,
    j0: usize,
    len: usize,
) -> (&mut [f32], &[f32]) {
    debug_assert_ne!(i, k, "row_pair_mut requires distinct rows");
    if i < k {
        let (lo, hi) = data.split_at_mut(k * n);
        (&mut lo[i * n + j0..i * n + j0 + len], &hi[j0..j0 + len])
    } else {
        let (lo, hi) = data.split_at_mut(i * n);
        (&mut hi[j0..j0 + len], &lo[k * n + j0..k * n + j0 + len])
    }
}

/// Phase-3 panel update, value-only, generic over the semiring: for every
/// cell of the `rows × cols` block at `dst` (row-major, `dst_stride`),
///
/// ```text
/// dst[r][c] = dst[r][c] ⊕ (⊕ over k < kk of col[r][k] ⊗ row[k][c])
/// ```
///
/// `col` is the `rows × kk` column-panel block (`col_stride`), `row` the
/// `kk × cols` row-panel block (`row_stride`).  All three regions must be
/// disjoint (the packed-panel path exists for callers whose column panel
/// aliases `dst` rows).  At [`MinPlus`] this is bitwise-identical to the
/// scalar i-k-j conditional-store loop — see the module docs for the
/// argument and the tests that pin it.
///
/// Dispatches once per call to the process-wide kernel ISA
/// ([`simd::active`]); every lane path is held to [`panel_reference`]
/// bitwise, so the dispatch is unobservable except in speed.
#[allow(clippy::too_many_arguments)]
pub fn panel<S: Semiring>(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    panel_with::<S>(
        simd::active(),
        dst,
        dst_stride,
        col,
        col_stride,
        row,
        row_stride,
        rows,
        cols,
        kk,
    );
}

/// [`panel`] on an explicit ISA — how benches price and the conformance
/// matrix pins every compiled lane path in one process.  Panics if `isa`
/// cannot run on this host: the typed rejection that replaces an
/// illegal-instruction fault (`FW_KERNEL` misuse is normally caught
/// earlier, at [`simd::resolve`]).
#[allow(clippy::too_many_arguments)]
pub fn panel_with<S: Semiring>(
    isa: Isa,
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    assert!(
        isa.available(),
        "kernel ISA {} is not available on this host (available: {})",
        isa.name(),
        simd::available_names()
    );
    debug_assert!(rows == 0 || cols == 0 || (rows - 1) * dst_stride + cols <= dst.len());
    debug_assert!(rows == 0 || kk == 0 || (rows - 1) * col_stride + kk <= col.len());
    debug_assert!(kk == 0 || cols == 0 || (kk - 1) * row_stride + cols <= row.len());
    match isa {
        Isa::Scalar => panel_scalar::<S>(
            dst, dst_stride, col, col_stride, row, row_stride, rows, cols, kk,
        ),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            simd::x86::panel_avx2::<S>(
                dst, dst_stride, col, col_stride, row, row_stride, rows, cols, kk,
            )
        },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe {
            simd::x86::panel_avx512::<S>(
                dst, dst_stride, col, col_stride, row, row_stride, rows, cols, kk,
            )
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            simd::arm::panel_neon::<S>(
                dst, dst_stride, col, col_stride, row, row_stride, rows, cols, kk,
            )
        },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel ISA {} is not compiled for this target", other.name()),
    }
}

/// The scalar `MR × NR` register-tiled panel — the PR 4 path, kept intact
/// as the [`Isa::Scalar`] lane shape and the first rung of the oracle
/// ladder (it is itself pinned against [`panel_reference`]).
#[allow(clippy::too_many_arguments)]
pub fn panel_scalar<S: Semiring>(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    let mut rb = 0;
    while rb + MR <= rows {
        let col_rows = &col[rb * col_stride..];
        let mut cb = 0;
        while cb + NR <= cols {
            micro_full::<S>(
                &mut dst[rb * dst_stride + cb..],
                dst_stride,
                col_rows,
                col_stride,
                &row[cb..],
                row_stride,
                kk,
            );
            cb += NR;
        }
        if cb < cols {
            micro_edge::<S>(
                &mut dst[rb * dst_stride + cb..],
                dst_stride,
                col_rows,
                col_stride,
                &row[cb..],
                row_stride,
                MR,
                cols - cb,
                kk,
            );
        }
        rb += MR;
    }
    if rb < rows {
        micro_edge::<S>(
            &mut dst[rb * dst_stride..],
            dst_stride,
            &col[rb * col_stride..],
            col_stride,
            row,
            row_stride,
            rows - rb,
            cols,
            kk,
        );
    }
}

/// `(min, +)` phase-3 panel update — [`panel`] monomorphized at
/// [`MinPlus`]; the entry point every distance tier calls.
pub fn minplus_panel(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    panel::<MinPlus>(dst, dst_stride, col, col_stride, row, row_stride, rows, cols, kk);
}

/// Scalar i-k-j conditional-store reference for [`panel`] — the loop shape
/// every phase-3 body had before the microkernel, kept as the one source
/// of truth the register path is differentially pinned against (kernel
/// unit tests and `tests/conformance.rs` both use it; mirrors how
/// `apsp::paths::solve` serves as the path tier's reference).  Not a hot
/// path: O(rows·kk·cols) with a store branch per accept.
pub fn panel_reference<S: Semiring>(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    for r in 0..rows {
        for k in 0..kk {
            let a = col[r * col_stride + k];
            if S::is_zero(a) {
                continue;
            }
            for c in 0..cols {
                let cand = S::extend(a, row[k * row_stride + c]);
                if S::improves(cand, dst[r * dst_stride + c]) {
                    dst[r * dst_stride + c] = cand;
                }
            }
        }
    }
}

/// `(min, +)` scalar reference — [`panel_reference`] at [`MinPlus`].
pub fn minplus_panel_reference(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    panel_reference::<MinPlus>(
        dst, dst_stride, col, col_stride, row, row_stride, rows, cols, kk,
    );
}

/// Full `MR × NR` register block: load the outputs once, fold the whole
/// k-walk in registers, store once.  The annihilator guard is hoisted out
/// of the inner sweep: a k-step is skipped only when the ⊕-fold of **all**
/// `MR` column-panel values is `ZERO` — which, `⊕` being a selection,
/// means every one of them is `ZERO` — and `ZERO` candidates never change
/// a `⊕`, so the skip is a bitwise no-op.  (At `(min, +)`: skip only when
/// all `MR` values are `+inf`.)
#[inline(always)]
fn micro_full<S: Semiring>(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    kk: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    for r in 0..MR {
        acc[r].copy_from_slice(&dst[r * dst_stride..r * dst_stride + NR]);
    }
    for k in 0..kk {
        let a = [
            col[k],
            col[col_stride + k],
            col[2 * col_stride + k],
            col[3 * col_stride + k],
        ];
        if S::is_zero(S::combine(S::combine(S::combine(a[0], a[1]), a[2]), a[3])) {
            continue;
        }
        let row_k = &row[k * row_stride..k * row_stride + NR];
        for r in 0..MR {
            let ar = a[r];
            for c in 0..NR {
                acc[r][c] = S::combine(acc[r][c], S::extend(ar, row_k[c]));
            }
        }
    }
    for r in 0..MR {
        dst[r * dst_stride..r * dst_stride + NR].copy_from_slice(&acc[r]);
    }
}

/// Ragged-edge fallback for blocks narrower than `MR × NR`: a plain scalar
/// fold per cell, still ascending in `k`, so edges carry the same bitwise
/// guarantee as the register path.  The SIMD panels reuse it for their
/// `cols % lanes` column remainders (`pub(crate)` for `apsp::simd`).
#[inline]
pub(crate) fn micro_edge<S: Semiring>(
    dst: &mut [f32],
    dst_stride: usize,
    col: &[f32],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    for r in 0..rows {
        let out = &mut dst[r * dst_stride..r * dst_stride + cols];
        for k in 0..kk {
            let a = col[r * col_stride + k];
            if S::is_zero(a) {
                continue;
            }
            let row_k = &row[k * row_stride..k * row_stride + cols];
            for c in 0..cols {
                out[c] = S::combine(out[c], S::extend(a, row_k[c]));
            }
        }
    }
}

/// Successor-tracking twin of [`panel`]: identical value arithmetic and k
/// order, with the strict [`Semiring::improves`] accept copying the
/// column-panel successor `colsucc[r][k]` — so values *and* successors are
/// bitwise equal to the scalar succ loop.  `dsucc` shares `dst_stride`;
/// `colsucc` shares `col_stride`.  Dispatches like [`panel`]; the SIMD
/// twins express the accept as a compare-mask select and replay the same
/// ascending-k sequence.
#[allow(clippy::too_many_arguments)]
pub fn panel_succ<S: Semiring>(
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    panel_succ_with::<S>(
        simd::active(),
        dst,
        dsucc,
        dst_stride,
        col,
        colsucc,
        col_stride,
        row,
        row_stride,
        rows,
        cols,
        kk,
    );
}

/// [`panel_succ`] on an explicit ISA; panics (typed) if `isa` cannot run
/// here — see [`panel_with`].
#[allow(clippy::too_many_arguments)]
pub fn panel_succ_with<S: Semiring>(
    isa: Isa,
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    assert!(
        isa.available(),
        "kernel ISA {} is not available on this host (available: {})",
        isa.name(),
        simd::available_names()
    );
    debug_assert!(rows == 0 || cols == 0 || (rows - 1) * dst_stride + cols <= dsucc.len());
    debug_assert!(rows == 0 || kk == 0 || (rows - 1) * col_stride + kk <= colsucc.len());
    match isa {
        Isa::Scalar => panel_succ_scalar::<S>(
            dst, dsucc, dst_stride, col, colsucc, col_stride, row, row_stride, rows, cols, kk,
        ),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            simd::x86::panel_succ_avx2::<S>(
                dst, dsucc, dst_stride, col, colsucc, col_stride, row, row_stride, rows, cols,
                kk,
            )
        },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe {
            simd::x86::panel_succ_avx512::<S>(
                dst, dsucc, dst_stride, col, colsucc, col_stride, row, row_stride, rows, cols,
                kk,
            )
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            simd::arm::panel_succ_neon::<S>(
                dst, dsucc, dst_stride, col, colsucc, col_stride, row, row_stride, rows, cols,
                kk,
            )
        },
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel ISA {} is not compiled for this target", other.name()),
    }
}

/// The scalar register-tiled successor panel (the [`Isa::Scalar`] lane
/// shape; PR 4 path, unchanged).
#[allow(clippy::too_many_arguments)]
pub fn panel_succ_scalar<S: Semiring>(
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    let mut rb = 0;
    while rb + MR <= rows {
        let col_rows = &col[rb * col_stride..];
        let csucc_rows = &colsucc[rb * col_stride..];
        let mut cb = 0;
        while cb + NR <= cols {
            micro_full_succ::<S>(
                &mut dst[rb * dst_stride + cb..],
                &mut dsucc[rb * dst_stride + cb..],
                dst_stride,
                col_rows,
                csucc_rows,
                col_stride,
                &row[cb..],
                row_stride,
                kk,
            );
            cb += NR;
        }
        if cb < cols {
            micro_edge_succ::<S>(
                &mut dst[rb * dst_stride + cb..],
                &mut dsucc[rb * dst_stride + cb..],
                dst_stride,
                col_rows,
                csucc_rows,
                col_stride,
                &row[cb..],
                row_stride,
                MR,
                cols - cb,
                kk,
            );
        }
        rb += MR;
    }
    if rb < rows {
        micro_edge_succ::<S>(
            &mut dst[rb * dst_stride..],
            &mut dsucc[rb * dst_stride..],
            dst_stride,
            &col[rb * col_stride..],
            &colsucc[rb * col_stride..],
            col_stride,
            row,
            row_stride,
            rows - rb,
            cols,
            kk,
        );
    }
}

/// `(min, +)` successor panel — [`panel_succ`] at [`MinPlus`].
#[allow(clippy::too_many_arguments)]
pub fn minplus_panel_succ(
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    panel_succ::<MinPlus>(
        dst, dsucc, dst_stride, col, colsucc, col_stride, row, row_stride, rows, cols, kk,
    );
}

/// `MR × NR` register block with successor accumulators.  The accept stays
/// branchy (the successor write needs the comparison anyway) but both
/// accumulator blocks live in registers/L1 across the whole k-walk, so the
/// store traffic of the scalar loop is still gone.
#[inline(always)]
fn micro_full_succ<S: Semiring>(
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    kk: usize,
) {
    let mut acc = [[0f32; NR]; MR];
    let mut sacc = [[0usize; NR]; MR];
    for r in 0..MR {
        acc[r].copy_from_slice(&dst[r * dst_stride..r * dst_stride + NR]);
        sacc[r].copy_from_slice(&dsucc[r * dst_stride..r * dst_stride + NR]);
    }
    for k in 0..kk {
        let a = [
            col[k],
            col[col_stride + k],
            col[2 * col_stride + k],
            col[3 * col_stride + k],
        ];
        if S::is_zero(S::combine(S::combine(S::combine(a[0], a[1]), a[2]), a[3])) {
            continue;
        }
        let row_k = &row[k * row_stride..k * row_stride + NR];
        for r in 0..MR {
            let ar = a[r];
            let sr = colsucc[r * col_stride + k];
            for c in 0..NR {
                let cand = S::extend(ar, row_k[c]);
                if S::improves(cand, acc[r][c]) {
                    acc[r][c] = cand;
                    sacc[r][c] = sr;
                }
            }
        }
    }
    for r in 0..MR {
        dst[r * dst_stride..r * dst_stride + NR].copy_from_slice(&acc[r]);
        dsucc[r * dst_stride..r * dst_stride + NR].copy_from_slice(&sacc[r]);
    }
}

/// Ragged-edge successor fallback (ascending k, strict accept — the scalar
/// order).  Also the SIMD succ panels' column-remainder path.
#[inline]
pub(crate) fn micro_edge_succ<S: Semiring>(
    dst: &mut [f32],
    dsucc: &mut [usize],
    dst_stride: usize,
    col: &[f32],
    colsucc: &[usize],
    col_stride: usize,
    row: &[f32],
    row_stride: usize,
    rows: usize,
    cols: usize,
    kk: usize,
) {
    for r in 0..rows {
        for k in 0..kk {
            let a = col[r * col_stride + k];
            if S::is_zero(a) {
                continue;
            }
            let sr = colsucc[r * col_stride + k];
            let row_k = &row[k * row_stride..k * row_stride + cols];
            for c in 0..cols {
                let cand = S::extend(a, row_k[c]);
                if S::improves(cand, dst[r * dst_stride + c]) {
                    dst[r * dst_stride + c] = cand;
                    dsucc[r * dst_stride + c] = sr;
                }
            }
        }
    }
}

/// Reusable packing buffers for column panels (and their successor twins).
///
/// Packing copies a `rows × kk` panel read at a large stride into a
/// contiguous tile — the coalescing analog of the paper's §4.3 layout
/// transform.  The in-place (`apsp::blocked`) and banded
/// (`apsp::parallel`) phase-3 drivers *must* pack: their column panel
/// shares rows with `dst`, and the copy is what turns the aliased region
/// into a disjoint input the kernel's borrow contract requires.  Detached
/// tiles (`superblock::minplus`) are contiguous already and skip it — see
/// [`should_pack`].
#[derive(Default)]
pub struct PanelBuf {
    dist: Vec<f32>,
    succ: Vec<usize>,
}

impl PanelBuf {
    /// Pack the `rows × kk` distance panel at `src` (row stride `stride`).
    pub fn pack_dist(&mut self, src: &[f32], stride: usize, rows: usize, kk: usize) {
        self.dist.clear();
        self.dist.reserve(rows * kk);
        for r in 0..rows {
            self.dist.extend_from_slice(&src[r * stride..r * stride + kk]);
        }
    }

    /// Pack the matching `rows × kk` successor panel.
    pub fn pack_succ(&mut self, src: &[usize], stride: usize, rows: usize, kk: usize) {
        self.succ.clear();
        self.succ.reserve(rows * kk);
        for r in 0..rows {
            self.succ.extend_from_slice(&src[r * stride..r * stride + kk]);
        }
    }

    /// The packed distance panel (contiguous, stride = kk).
    pub fn dist(&self) -> &[f32] {
        &self.dist
    }

    /// The packed successor panel (contiguous, stride = kk).
    pub fn succ(&self) -> &[usize] {
        &self.succ
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::semiring::{BoolOrAnd, MaxMin, MinMax};
    use crate::util::prng::Rng;

    /// The bitwise oracle is the exported scalar loop itself.
    use super::minplus_panel_reference as scalar_reference;

    fn scalar_reference_succ(
        dst: &mut [f32],
        dsucc: &mut [usize],
        ds: usize,
        col: &[f32],
        colsucc: &[usize],
        cs: usize,
        row: &[f32],
        rs: usize,
        rows: usize,
        cols: usize,
        kk: usize,
    ) {
        for r in 0..rows {
            for k in 0..kk {
                let a = col[r * cs + k];
                if !a.is_finite() {
                    continue;
                }
                let s = colsucc[r * cs + k];
                for c in 0..cols {
                    let cand = a + row[k * rs + c];
                    if cand < dst[r * ds + c] {
                        dst[r * ds + c] = cand;
                        dsucc[r * ds + c] = s;
                    }
                }
            }
        }
    }

    /// `rows × cols` buffer with an `inf_density` fraction of `+inf`
    /// entries (the finiteness-guard stressor), embedded in a row-major
    /// buffer of stride `stride ≥ cols`.
    fn arb_panel(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        stride: usize,
        inf_density: f64,
    ) -> Vec<f32> {
        assert!(stride >= cols);
        let mut out = vec![f32::INFINITY; rows.max(1) * stride];
        for r in 0..rows {
            for c in 0..cols {
                out[r * stride + c] = if rng.next_f64() < inf_density {
                    f32::INFINITY
                } else {
                    (rng.next_f64() * 15.0 - 5.0) as f32
                };
            }
        }
        out
    }

    /// Like [`arb_panel`] but in a semiring's domain: `zero_density`
    /// fraction of `S::ZERO` cells, the rest positive selections.
    fn arb_panel_semiring<S: Semiring>(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        stride: usize,
        zero_density: f64,
    ) -> Vec<f32> {
        assert!(stride >= cols);
        let mut out = vec![S::ZERO; rows.max(1) * stride];
        for r in 0..rows {
            for c in 0..cols {
                out[r * stride + c] = if rng.next_f64() < zero_density {
                    S::ZERO
                } else {
                    (0.0625 * (1 + rng.next_u64() % 64) as f64) as f32
                };
            }
        }
        out
    }

    fn bitwise_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn matches_scalar_reference_across_tiles_and_densities() {
        // the pinned contract: register tiling, the hoisted all-inf guard,
        // and write-once accumulation are bitwise no-ops for every tile
        // size (incl. 33: ragged in both dimensions) and inf density
        let mut rng = Rng::new(0xA11CE);
        for s in [8usize, 16, 32, 33] {
            for density in [0.0, 0.3, 0.9, 1.0] {
                let stride = s + 7; // non-trivial strides
                let base = arb_panel(&mut rng, s, s, stride, density);
                let col = arb_panel(&mut rng, s, s, stride, density);
                let row = arb_panel(&mut rng, s, s, stride, density);

                let mut expect = base.clone();
                scalar_reference(&mut expect, stride, &col, stride, &row, stride, s, s, s);
                let mut got = base.clone();
                minplus_panel(&mut got, stride, &col, stride, &row, stride, s, s, s);
                assert!(bitwise_eq(&expect, &got), "s={s} density={density}");
            }
        }
    }

    #[test]
    fn generic_semirings_match_their_scalar_reference() {
        // the register tiling is a ⊕-fold reassociation; for the
        // selection-only semirings every fold order yields the exact
        // optimum, so kernel and reference must agree to the bit
        fn check<S: Semiring>(rng: &mut Rng) {
            for s in [8usize, 16, 33] {
                for density in [0.0, 0.4, 1.0] {
                    let stride = s + 5;
                    let base = arb_panel_semiring::<S>(rng, s, s, stride, density);
                    let col = arb_panel_semiring::<S>(rng, s, s, stride, density);
                    let row = arb_panel_semiring::<S>(rng, s, s, stride, density);
                    let mut expect = base.clone();
                    panel_reference::<S>(
                        &mut expect, stride, &col, stride, &row, stride, s, s, s,
                    );
                    let mut got = base.clone();
                    panel::<S>(&mut got, stride, &col, stride, &row, stride, s, s, s);
                    assert!(bitwise_eq(&expect, &got), "{} s={s} d={density}", S::NAME);
                }
            }
        }
        let mut rng = Rng::new(0x5E81);
        check::<MaxMin>(&mut rng);
        check::<MinMax>(&mut rng);
        check::<BoolOrAnd>(&mut rng);
    }

    #[test]
    fn generic_succ_twin_matches_reference_accept_order() {
        // ascending-k strict accept: the succ kernel must pick the same
        // successor as a scalar replay for every semiring
        fn check<S: Semiring>(rng: &mut Rng) {
            let s = 16;
            let stride = s + 3;
            let base = arb_panel_semiring::<S>(rng, s, s, stride, 0.3);
            let col = arb_panel_semiring::<S>(rng, s, s, stride, 0.3);
            let row = arb_panel_semiring::<S>(rng, s, s, stride, 0.3);
            let base_succ: Vec<usize> = (0..s * stride).collect();
            let col_succ: Vec<usize> = (0..s * stride).map(|v| v + 10_000).collect();
            // scalar replay of the generic accept
            let mut ed = base.clone();
            let mut es = base_succ.clone();
            for r in 0..s {
                for k in 0..s {
                    let a = col[r * stride + k];
                    if S::is_zero(a) {
                        continue;
                    }
                    for c in 0..s {
                        let cand = S::extend(a, row[k * stride + c]);
                        if S::improves(cand, ed[r * stride + c]) {
                            ed[r * stride + c] = cand;
                            es[r * stride + c] = col_succ[r * stride + k];
                        }
                    }
                }
            }
            let mut gd = base.clone();
            let mut gs = base_succ.clone();
            panel_succ::<S>(
                &mut gd, &mut gs, stride, &col, &col_succ, stride, &row, stride, s, s, s,
            );
            assert!(bitwise_eq(&ed, &gd), "{} dist", S::NAME);
            assert_eq!(es, gs, "{} succ", S::NAME);
        }
        let mut rng = Rng::new(0x5E82);
        check::<MaxMin>(&mut rng);
        check::<MinMax>(&mut rng);
        check::<BoolOrAnd>(&mut rng);
    }

    #[test]
    fn ragged_rows_cols_k_match_scalar() {
        // every remainder combination around the MR×NR register block
        let mut rng = Rng::new(0xBEEF);
        for rows in [1usize, 3, 4, 5, 7, 9] {
            for cols in [1usize, 7, 8, 9, 15, 17] {
                for kk in [0usize, 1, 5, 8, 13] {
                    let ks = kk.max(1); // col/row strides (kk = 0 still allocates)
                    let base = arb_panel(&mut rng, rows, cols, cols, 0.4);
                    let col = arb_panel(&mut rng, rows, ks, ks, 0.4);
                    let row = arb_panel(&mut rng, ks, cols, cols, 0.4);
                    let mut expect = base.clone();
                    scalar_reference(&mut expect, cols, &col, ks, &row, cols, rows, cols, kk);
                    let mut got = base.clone();
                    minplus_panel(&mut got, cols, &col, ks, &row, cols, rows, cols, kk);
                    assert!(bitwise_eq(&expect, &got), "rows={rows} cols={cols} kk={kk}");
                }
            }
        }
    }

    #[test]
    fn packed_equals_unpacked_bitwise() {
        // PanelBuf packing is a pure copy: the kernel on the packed panel
        // (stride = kk) must match the kernel on the strided original
        let mut rng = Rng::new(0xC0FFEE);
        for s in [8usize, 16, 32, 33] {
            let stride = 2 * s + 3;
            let base = arb_panel(&mut rng, s, s, stride, 0.3);
            let col = arb_panel(&mut rng, s, s, stride, 0.3);
            let row = arb_panel(&mut rng, s, s, stride, 0.3);

            let mut strided = base.clone();
            minplus_panel(&mut strided, stride, &col, stride, &row, stride, s, s, s);

            let mut pack = PanelBuf::default();
            pack.pack_dist(&col, stride, s, s);
            let mut packed = base.clone();
            minplus_panel(&mut packed, stride, pack.dist(), s, &row, stride, s, s, s);
            assert!(bitwise_eq(&strided, &packed), "s={s}");
        }
    }

    #[test]
    fn succ_twin_matches_scalar_bitwise_dist_and_succ() {
        let mut rng = Rng::new(0xD00D);
        for s in [8usize, 16, 32, 33] {
            for density in [0.0, 0.4, 0.95] {
                let stride = s + 5;
                let base = arb_panel(&mut rng, s, s, stride, density);
                let col = arb_panel(&mut rng, s, s, stride, density);
                let row = arb_panel(&mut rng, s, s, stride, density);
                let base_succ: Vec<usize> = (0..s * stride).collect();
                let col_succ: Vec<usize> = (0..s * stride).map(|v| v + 10_000).collect();

                let mut ed = base.clone();
                let mut es = base_succ.clone();
                scalar_reference_succ(
                    &mut ed, &mut es, stride, &col, &col_succ, stride, &row, stride, s, s, s,
                );
                let mut gd = base.clone();
                let mut gs = base_succ.clone();
                minplus_panel_succ(
                    &mut gd, &mut gs, stride, &col, &col_succ, stride, &row, stride, s, s, s,
                );
                assert!(bitwise_eq(&ed, &gd), "dist s={s} density={density}");
                assert_eq!(es, gs, "succ s={s} density={density}");
            }
        }
    }

    #[test]
    fn succ_twin_distances_equal_distance_only_kernel() {
        // the cross-twin contract the path tier leans on
        let mut rng = Rng::new(0xFACE);
        let s = 32;
        let base = arb_panel(&mut rng, s, s, s, 0.5);
        let col = arb_panel(&mut rng, s, s, s, 0.5);
        let row = arb_panel(&mut rng, s, s, s, 0.5);
        let mut dist_only = base.clone();
        minplus_panel(&mut dist_only, s, &col, s, &row, s, s, s, s);
        let mut with_succ = base.clone();
        let mut succ = vec![0usize; s * s];
        let col_succ = vec![7usize; s * s];
        minplus_panel_succ(
            &mut with_succ, &mut succ, s, &col, &col_succ, s, &row, s, s, s, s,
        );
        assert!(bitwise_eq(&dist_only, &with_succ));
    }

    #[test]
    fn relax_row_equals_branchy_accept() {
        let mut rng = Rng::new(0x5EED);
        for _ in 0..50 {
            let len = 1 + (rng.next_u64() % 40) as usize;
            let mut branchy = arb_panel(&mut rng, 1, len, len, 0.3);
            let row_k = arb_panel(&mut rng, 1, len, len, 0.3);
            let wik = if rng.next_f64() < 0.2 {
                f32::INFINITY
            } else {
                (rng.next_f64() * 10.0 - 3.0) as f32
            };
            let mut branchless = branchy.clone();
            for j in 0..len {
                let cand = wik + row_k[j];
                if cand < branchy[j] {
                    branchy[j] = cand;
                }
            }
            relax_row(&mut branchless, &row_k, wik);
            assert!(bitwise_eq(&branchy, &branchless));
        }
    }

    #[test]
    fn row_pair_mut_returns_disjoint_rows_both_orders() {
        let n = 6;
        let mut data: Vec<f32> = (0..n * n).map(|v| v as f32).collect();
        {
            let (out, row_k) = row_pair_mut(&mut data, n, 1, 4, 2, 3);
            assert_eq!(&out[..], &[8.0, 9.0, 10.0][..]); // row 1, cols 2..5
            assert_eq!(row_k, &[26.0, 27.0, 28.0][..]); // row 4, cols 2..5
            out[0] = -1.0;
        }
        {
            let (out, row_k) = row_pair_mut(&mut data, n, 4, 1, 0, 2);
            assert_eq!(&out[..], &[24.0, 25.0][..]); // row 4
            assert_eq!(row_k, &[6.0, 7.0][..]); // row 1 (col 0..2)
        }
        assert_eq!(data[8], -1.0); // write landed
    }

    #[test]
    fn all_infinite_panel_is_a_no_op() {
        // the hoisted guard path: a fully unreachable column panel leaves
        // dst untouched (and is the fast exit the guard exists for)
        let s = 16;
        let mut rng = Rng::new(0x1F1F);
        let base = arb_panel(&mut rng, s, s, s, 0.2);
        let col = vec![f32::INFINITY; s * s];
        let row = arb_panel(&mut rng, s, s, s, 0.2);
        let mut got = base.clone();
        minplus_panel(&mut got, s, &col, s, &row, s, s, s, s);
        assert!(bitwise_eq(&base, &got));
    }

    #[test]
    fn all_zero_panel_is_a_no_op_per_semiring() {
        // the generic guard: a column panel of annihilators leaves dst
        // untouched under every instance
        fn check<S: Semiring>(rng: &mut Rng) {
            let s = 16;
            let base = arb_panel_semiring::<S>(rng, s, s, s, 0.2);
            let col = vec![S::ZERO; s * s];
            let row = arb_panel_semiring::<S>(rng, s, s, s, 0.2);
            let mut got = base.clone();
            panel::<S>(&mut got, s, &col, s, &row, s, s, s, s);
            assert!(bitwise_eq(&base, &got), "{}", S::NAME);
        }
        let mut rng = Rng::new(0x2F2F);
        check::<MaxMin>(&mut rng);
        check::<MinMax>(&mut rng);
        check::<BoolOrAnd>(&mut rng);
    }

    #[test]
    fn should_pack_heuristic_shape() {
        assert!(!should_pack(32, 32)); // contiguous detached tile
        assert!(!should_pack(64, 64));
        assert!(should_pack(256, 32)); // large-n in-place panel
        assert!(should_pack(4096, 512));
        assert!(!should_pack(96, 32)); // small n: panel fits L1 anyway
    }

    #[test]
    fn zero_sized_calls_are_no_ops() {
        let mut dst: Vec<f32> = vec![1.0; 8];
        minplus_panel(&mut dst, 8, &[], 1, &[], 1, 0, 8, 0);
        minplus_panel(&mut dst, 8, &[], 1, &[], 1, 1, 0, 0);
        assert!(dst.iter().all(|v| *v == 1.0));
        let mut pack = PanelBuf::default();
        pack.pack_dist(&[], 4, 0, 0);
        assert!(pack.dist().is_empty());
    }

    #[test]
    fn every_available_isa_matches_scalar_reference() {
        // the dispatch contract: each compiled-and-runnable lane path is a
        // bitwise no-op relative to the scalar reference, incl. tile 33
        // (ragged rows, cols, and a mid-panel lane remainder)
        let mut rng = Rng::new(0x51D0);
        for isa in simd::available_isas() {
            for s in [8usize, 16, 32, 33] {
                for density in [0.0, 0.3, 1.0] {
                    let stride = s + 7;
                    let base = arb_panel(&mut rng, s, s, stride, density);
                    let col = arb_panel(&mut rng, s, s, stride, density);
                    let row = arb_panel(&mut rng, s, s, stride, density);
                    let mut expect = base.clone();
                    scalar_reference(&mut expect, stride, &col, stride, &row, stride, s, s, s);
                    let mut got = base.clone();
                    panel_with::<MinPlus>(isa, &mut got, stride, &col, stride, &row, stride, s, s, s);
                    assert!(
                        bitwise_eq(&expect, &got),
                        "isa={} s={s} density={density}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn every_available_isa_ragged_lane_remainders_match() {
        // n % lanes != 0 in every combination around the widest lane count
        let mut rng = Rng::new(0x51D1);
        for isa in simd::available_isas() {
            for rows in [1usize, 3, 5] {
                for cols in [1usize, 7, 9, 15, 17, 31] {
                    for kk in [1usize, 5, 13] {
                        let base = arb_panel(&mut rng, rows, cols, cols, 0.4);
                        let col = arb_panel(&mut rng, rows, kk, kk, 0.4);
                        let row = arb_panel(&mut rng, kk, cols, cols, 0.4);
                        let mut expect = base.clone();
                        scalar_reference(&mut expect, cols, &col, kk, &row, cols, rows, cols, kk);
                        let mut got = base.clone();
                        panel_with::<MinPlus>(isa, &mut got, cols, &col, kk, &row, cols, rows, cols, kk);
                        assert!(
                            bitwise_eq(&expect, &got),
                            "isa={} rows={rows} cols={cols} kk={kk}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_available_isa_succ_twin_matches_scalar() {
        let mut rng = Rng::new(0x51D2);
        for isa in simd::available_isas() {
            for s in [8usize, 17, 33] {
                let stride = s + 5;
                let base = arb_panel(&mut rng, s, s, stride, 0.4);
                let col = arb_panel(&mut rng, s, s, stride, 0.4);
                let row = arb_panel(&mut rng, s, s, stride, 0.4);
                let base_succ: Vec<usize> = (0..s * stride).collect();
                let col_succ: Vec<usize> = (0..s * stride).map(|v| v + 10_000).collect();
                let mut ed = base.clone();
                let mut es = base_succ.clone();
                scalar_reference_succ(
                    &mut ed, &mut es, stride, &col, &col_succ, stride, &row, stride, s, s, s,
                );
                let mut gd = base.clone();
                let mut gs = base_succ.clone();
                panel_succ_with::<MinPlus>(
                    isa, &mut gd, &mut gs, stride, &col, &col_succ, stride, &row, stride, s, s, s,
                );
                assert!(bitwise_eq(&ed, &gd), "isa={} dist s={s}", isa.name());
                assert_eq!(es, gs, "isa={} succ s={s}", isa.name());
            }
        }
    }

    #[test]
    fn every_available_isa_relax_row_matches_scalar() {
        let mut rng = Rng::new(0x51D3);
        for isa in simd::available_isas() {
            for _ in 0..25 {
                let len = 1 + (rng.next_u64() % 40) as usize;
                let base = arb_panel(&mut rng, 1, len, len, 0.3);
                let row_k = arb_panel(&mut rng, 1, len, len, 0.3);
                let wik = (rng.next_f64() * 10.0 - 3.0) as f32;
                let mut expect = base.clone();
                relax_row_scalar::<MinPlus>(&mut expect, &row_k, wik);
                let mut got = base.clone();
                relax_row_with::<MinPlus>(isa, &mut got, &row_k, wik);
                assert!(bitwise_eq(&expect, &got), "isa={} len={len}", isa.name());
            }
        }
    }

    #[test]
    #[should_panic(expected = "not available on this host")]
    fn panel_with_unavailable_isa_panics_with_typed_message() {
        // the other family's ISA can never run here — the assert must fire
        // before any intrinsic does
        #[cfg(target_arch = "x86_64")]
        let foreign = simd::Isa::Neon;
        #[cfg(not(target_arch = "x86_64"))]
        let foreign = simd::Isa::Avx2;
        let mut dst = vec![0.0f32; 64];
        let col = vec![0.0f32; 64];
        let row = vec![0.0f32; 64];
        panel_with::<MinPlus>(foreign, &mut dst, 8, &col, 8, &row, 8, 8, 8, 8);
    }
}

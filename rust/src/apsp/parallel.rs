//! Multithreaded blocked Floyd-Warshall.
//!
//! Phase 3 is Θ(n³) of the total work and its tiles are mutually
//! independent within a stage (both dependencies — the row and column
//! panels — are final), so it parallelizes embarrassingly.  This solver
//! runs phases 1–2 sequentially (Θ(n²·s) work) and fans phase 3 out over
//! `threads` row bands using `std::thread::scope`; each band drives the
//! shared register-tiled microkernel ([`kernel::minplus_panel`]) over its
//! tiles, packing the band-local column-panel tile once per tile row.
//! The kernel dispatches to the runtime-selected SIMD ISA
//! ([`crate::apsp::simd`]); the choice is process-wide and cached, so
//! every band runs the same lane shape.
//!
//! Safety model (no `unsafe`): before phase 3, the stage's row panel is
//! copied to a scratch buffer (every thread reads it, one thread owns its
//! rows).  The matrix rows are then split into disjoint `&mut` bands with
//! `chunks_mut`; each band's column-panel dependency (`w[i][k]`) lives in
//! the band's own rows and is packed into a band-local buffer
//! ([`kernel::PanelBuf`]) — which is also what presents the kernel with
//! disjoint inputs despite the panel aliasing the band.
//!
//! Sizes that are not a tile multiple pad to the next multiple and
//! truncate, exactly like [`super::blocked`] (and bitwise equal to it).
//!
//! Like the sequential tier, the banded drivers are generic over the
//! [`Semiring`] ([`solve_semiring`], [`solve_paths_semiring`]); the public
//! `(min, +)` entry points are the generics monomorphized at
//! [`MinPlus`](crate::apsp::semiring::MinPlus), bitwise-pinned as before.

use std::time::Instant;

use super::blocked::PhaseProfile;
use super::kernel::{self, PanelBuf};
use super::paths::{self, PathsResult};
use super::semiring::{padded_semiring, MinPlus, Semiring};
use crate::graph::DistMatrix;

/// Blocked FW with tile size `s` and phase-3 parallelism of `threads`.
pub fn solve(w: &DistMatrix, s: usize, threads: usize) -> DistMatrix {
    solve_semiring::<MinPlus>(w, s, threads)
}

/// [`solve`] with a per-phase timing split — bitwise-identical output
/// (`Instant` reads happen between the sequential phase sections and
/// around the phase-3 fan-out, never inside a band).
pub fn solve_profiled(w: &DistMatrix, s: usize, threads: usize) -> (DistMatrix, PhaseProfile) {
    solve_profiled_semiring::<MinPlus>(w, s, threads)
}

/// Generic profiled banded solve — [`solve_profiled`] for any
/// [`Semiring`].  Degenerate parameters fall back to the sequential
/// profiled solver (same dispatch rule as [`solve_in_place_semiring`]).
pub fn solve_profiled_semiring<S: Semiring>(
    w: &DistMatrix,
    s: usize,
    threads: usize,
) -> (DistMatrix, PhaseProfile) {
    let n = w.n();
    if n == 0 {
        return (w.clone(), PhaseProfile::default());
    }
    if threads <= 1 || s == 0 || (n % s != 0 && n < s) {
        return super::blocked::solve_profiled_semiring::<S>(w, s);
    }
    if n % s != 0 {
        let padded_n = n.div_ceil(s) * s;
        let (padded, prof) =
            solve_profiled_semiring::<S>(&padded_semiring::<S>(w, padded_n), s, threads);
        return (padded.truncated(n), prof);
    }
    let mut out = w.clone();
    let mut prof = PhaseProfile::default();
    let nb = n / s;
    let mut row_panel = vec![0f32; s * n];
    for b in 0..nb {
        let ks = b * s;
        let t0 = Instant::now();
        super::blocked::phase1_diag_semiring::<S>(&mut out, ks, s);
        let t1 = Instant::now();
        for jb in 0..nb {
            if jb != b {
                super::blocked::phase2_row_tile_semiring::<S>(&mut out, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                super::blocked::phase2_col_tile_semiring::<S>(&mut out, ks, ib * s, s);
            }
        }
        let t2 = Instant::now();
        // snapshot + fan-out, accounted as phase 3 like the sequential twin
        row_panel.copy_from_slice(&out.as_slice()[ks * n..(ks + s) * n]);
        phase3_parallel::<S>(&mut out, &row_panel, ks, s, threads);
        prof.phase1_seconds += (t1 - t0).as_secs_f64();
        prof.phase2_seconds += (t2 - t1).as_secs_f64();
        prof.phase3_seconds += t2.elapsed().as_secs_f64();
        prof.rounds += 1;
    }
    (out, prof)
}

/// Generic banded blocked FW — [`solve`] over any [`Semiring`].  Expects
/// the matrix in the semiring's domain (`S::ONE` diagonal, `S::ZERO`
/// absent edges).
pub fn solve_semiring<S: Semiring>(w: &DistMatrix, s: usize, threads: usize) -> DistMatrix {
    let mut out = w.clone();
    solve_in_place_semiring::<S>(&mut out, s, threads);
    out
}

/// Parallel blocked FW with successor tracking — the same band
/// decomposition as [`solve`], with each phase-3 band carrying its own
/// disjoint successor rows.
///
/// The safety model extends unchanged: the distance row panel is
/// snapshotted before phase 3 (every band reads it), while the successor
/// source of a phase-3 update is `succ[i][k]` — the *column-panel* entry,
/// which lives in the band's own rows and is packed alongside the
/// distances — so no successor snapshot is needed and bands stay disjoint
/// in both matrices.  Distances are bitwise equal to [`solve`] (and hence
/// to `blocked::solve`); non-multiple sizes pad and truncate; degenerate
/// parameters fall back to [`super::blocked::solve_paths`].
pub fn solve_paths(w: &DistMatrix, s: usize, threads: usize) -> PathsResult {
    solve_paths_semiring::<MinPlus>(w, s, threads)
}

/// Generic banded blocked FW with successor tracking — [`solve_paths`]
/// over any [`Semiring`].
pub fn solve_paths_semiring<S: Semiring>(w: &DistMatrix, s: usize, threads: usize) -> PathsResult {
    let n = w.n();
    if n == 0 {
        return PathsResult::from_parts(w.clone(), Vec::new());
    }
    if threads <= 1 || s == 0 || (n % s != 0 && n < s) {
        return super::blocked::solve_paths_semiring::<S>(w, s);
    }
    if n % s != 0 {
        let padded_n = n.div_ceil(s) * s;
        return solve_paths_semiring::<S>(&padded_semiring::<S>(w, padded_n), s, threads)
            .truncated(n);
    }
    let mut dist = w.clone();
    let mut succ = paths::init_succ_semiring::<S>(w);
    let nb = n / s;
    let mut row_panel = vec![0f32; s * n];
    for b in 0..nb {
        let ks = b * s;
        super::blocked::phase1_diag_succ_semiring::<S>(&mut dist, &mut succ, ks, s);
        for jb in 0..nb {
            if jb != b {
                super::blocked::phase2_row_tile_succ_semiring::<S>(
                    &mut dist, &mut succ, ks, jb * s, s,
                );
            }
        }
        for ib in 0..nb {
            if ib != b {
                super::blocked::phase2_col_tile_succ_semiring::<S>(
                    &mut dist, &mut succ, ks, ib * s, s,
                );
            }
        }
        row_panel.copy_from_slice(&dist.as_slice()[ks * n..(ks + s) * n]);
        phase3_parallel_succ::<S>(&mut dist, &mut succ, &row_panel, ks, s, threads);
    }
    PathsResult::from_parts(dist, succ)
}

/// Fan the stage's doubly-dependent tiles out over row bands, tracking
/// successors.  Mirrors [`phase3_parallel`] with a second banded matrix.
fn phase3_parallel_succ<S: Semiring>(
    w: &mut DistMatrix,
    succ: &mut [usize],
    row_panel: &[f32],
    ks: usize,
    s: usize,
    threads: usize,
) {
    let n = w.n();
    let nb = n / s;
    let b = ks / s;
    let blocks_per_band = nb.div_ceil(threads);
    let rows_per_band = blocks_per_band * s;
    let data = w.as_mut_slice();
    std::thread::scope(|scope| {
        let bands = data
            .chunks_mut(rows_per_band * n)
            .zip(succ.chunks_mut(rows_per_band * n));
        for (band_idx, (band, succ_band)) in bands.enumerate() {
            let row_panel = &row_panel[..];
            scope.spawn(move || {
                let mut pack = PanelBuf::default();
                let first_block = band_idx * blocks_per_band;
                let band_blocks = band.len() / (s * n);
                for ib_local in 0..band_blocks {
                    let ib = first_block + ib_local;
                    if ib == b {
                        continue; // panel rows are final
                    }
                    let is = ib_local * s;
                    pack.pack_dist(&band[is * n + ks..], n, s, s);
                    pack.pack_succ(&succ_band[is * n + ks..], n, s, s);
                    for jb in 0..nb {
                        if jb == b {
                            continue;
                        }
                        let js = jb * s;
                        kernel::panel_succ::<S>(
                            &mut band[is * n + js..],
                            &mut succ_band[is * n + js..],
                            n,
                            pack.dist(),
                            pack.succ(),
                            s,
                            &row_panel[js..],
                            n,
                            s,
                            s,
                            s,
                        );
                    }
                }
            });
        }
    });
}

/// In-place parallel blocked FW.  Falls back to the sequential blocked
/// solver for degenerate parameters; non-multiple sizes pad and truncate.
pub fn solve_in_place(w: &mut DistMatrix, s: usize, threads: usize) {
    solve_in_place_semiring::<MinPlus>(w, s, threads);
}

/// Generic in-place banded blocked FW — the driver behind
/// [`solve_in_place`].
pub fn solve_in_place_semiring<S: Semiring>(w: &mut DistMatrix, s: usize, threads: usize) {
    let n = w.n();
    if n == 0 {
        return;
    }
    if threads <= 1 || s == 0 || (n % s != 0 && n < s) {
        super::blocked::solve_in_place_semiring::<S>(w, s);
        return;
    }
    if n % s != 0 {
        let padded_n = n.div_ceil(s) * s;
        let mut padded = padded_semiring::<S>(w, padded_n);
        solve_in_place_semiring::<S>(&mut padded, s, threads);
        *w = padded.truncated(n);
        return;
    }
    let nb = n / s;
    let mut row_panel = vec![0f32; s * n];
    for b in 0..nb {
        let ks = b * s;
        super::blocked::phase1_diag_semiring::<S>(w, ks, s);
        for jb in 0..nb {
            if jb != b {
                super::blocked::phase2_row_tile_semiring::<S>(w, ks, jb * s, s);
            }
        }
        for ib in 0..nb {
            if ib != b {
                super::blocked::phase2_col_tile_semiring::<S>(w, ks, ib * s, s);
            }
        }
        // snapshot the (final) row panel so phase-3 bands can read it freely
        row_panel.copy_from_slice(&w.as_slice()[ks * n..(ks + s) * n]);
        phase3_parallel::<S>(w, &row_panel, ks, s, threads);
    }
}

/// Fan the stage's doubly-dependent tiles out over row bands; each band
/// packs its column-panel tile once per tile row and sweeps the row of
/// tiles through the microkernel.
fn phase3_parallel<S: Semiring>(
    w: &mut DistMatrix,
    row_panel: &[f32],
    ks: usize,
    s: usize,
    threads: usize,
) {
    let n = w.n();
    let nb = n / s;
    let b = ks / s;
    // Each work item is one row-block (s contiguous rows).  Distribute
    // row-blocks round-robin over bands of `rows_per_band` so chunks_mut can
    // hand out disjoint row ranges.
    let blocks_per_band = nb.div_ceil(threads);
    let rows_per_band = blocks_per_band * s;
    let data = w.as_mut_slice();
    std::thread::scope(|scope| {
        for (band_idx, band) in data.chunks_mut(rows_per_band * n).enumerate() {
            let row_panel = &row_panel[..];
            scope.spawn(move || {
                let mut pack = PanelBuf::default();
                let first_block = band_idx * blocks_per_band;
                let band_blocks = band.len() / (s * n);
                for ib_local in 0..band_blocks {
                    let ib = first_block + ib_local;
                    if ib == b {
                        continue; // panel rows are final
                    }
                    let is = ib_local * s;
                    pack.pack_dist(&band[is * n + ks..], n, s, s);
                    for jb in 0..nb {
                        if jb == b {
                            continue;
                        }
                        let js = jb * s;
                        kernel::panel::<S>(
                            &mut band[is * n + js..],
                            n,
                            pack.dist(),
                            s,
                            &row_panel[js..],
                            n,
                            s,
                            s,
                            s,
                        );
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::naive;
    use crate::graph::{generators, DistMatrix};

    fn assert_matches_naive(g: &DistMatrix, s: usize, threads: usize) {
        let expect = naive::solve(g);
        let got = solve(g, s, threads);
        assert!(
            got.allclose(&expect, 1e-5, 1e-6),
            "parallel(s={s}, t={threads}) diverges by {}",
            got.max_abs_diff(&expect)
        );
    }

    #[test]
    fn matches_naive_various_thread_counts() {
        let g = generators::erdos_renyi(128, 0.3, 23);
        for threads in [1, 2, 3, 4, 8] {
            assert_matches_naive(&g, 32, threads);
        }
    }

    #[test]
    fn threads_exceed_blocks() {
        // more threads than row blocks: some bands are empty
        let g = generators::erdos_renyi(64, 0.4, 29);
        assert_matches_naive(&g, 32, 16);
    }

    #[test]
    fn uneven_band_split() {
        // nb=5 blocks over 2 threads → bands of 3 and 2 blocks
        let g = generators::erdos_renyi(80, 0.35, 31);
        assert_matches_naive(&g, 16, 2);
    }

    #[test]
    fn negative_weights() {
        let g = generators::layered_dag(8, 8, 41);
        assert_matches_naive(&g, 16, 4);
    }

    #[test]
    fn bitwise_equal_to_sequential_blocked() {
        // same relaxation order within every tile ⇒ identical floats
        let g = generators::erdos_renyi(96, 0.3, 37);
        let seq = super::super::blocked::solve(&g, 32);
        let par = solve(&g, 32, 4);
        assert_eq!(seq, par);
    }

    #[test]
    fn non_multiple_pads_bitwise_like_blocked() {
        // the padded path re-enters the banded solver, and bands never
        // change relaxation order — so even padded sizes match the
        // sequential blocked solver bit for bit
        let g = generators::erdos_renyi(48, 0.4, 43);
        assert_matches_naive(&g, 32, 4); // 48 % 32 != 0 → pads to 64
        assert_eq!(solve(&g, 32, 4), super::super::blocked::solve(&g, 32));
        assert_matches_naive(&g, 16, 0); // 0 threads → sequential
    }

    #[test]
    fn paths_distances_bitwise_equal_across_thread_counts() {
        // same contract as the distance solver: thread count cannot perturb
        // a bit, and the path variant matches the distance-only output
        let g = generators::erdos_renyi(96, 0.3, 47);
        let dist_only = solve(&g, 32, 4);
        for threads in [1, 2, 3, 4, 8] {
            let r = solve_paths(&g, 32, threads);
            assert_eq!(r.dist, dist_only, "threads={threads}");
        }
    }

    #[test]
    fn paths_successors_identical_to_sequential_blocked() {
        // bands only re-partition the same relaxation order, so even the
        // successor matrix (not just distances) matches blocked::solve_paths
        let g = generators::erdos_renyi(80, 0.35, 53);
        let seq = super::super::blocked::solve_paths(&g, 16);
        for threads in [2, 5] {
            let par = solve_paths(&g, 16, threads);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn paths_reconstruct_on_negative_weights() {
        let g = generators::layered_dag(8, 8, 59); // negative edges, no cycles
        let r = solve_paths(&g, 16, 4);
        for i in 0..g.n() {
            for j in 0..g.n() {
                match r.path(i, j) {
                    Some(_) => {
                        let w = r.path_weight(&g, i, j).expect("valid edge walk");
                        let d = r.dist.get(i, j) as f64;
                        assert!((w - d).abs() < 1e-3, "({i},{j}): {w} vs {d}");
                    }
                    None => assert!(!r.dist.get(i, j).is_finite() || i == j),
                }
            }
        }
    }

    #[test]
    fn generic_semirings_banded_equal_sequential() {
        // bands re-partition, never re-order — so banded generic output is
        // exactly the sequential generic output (selection semirings are
        // exact, minplus is bitwise by the shared schedule)
        use crate::apsp::semiring::{MaxMin, Objective};
        let g = generators::erdos_renyi(80, 0.3, 67);
        let prepared = Objective::Bottleneck.prepare(&g).unwrap();
        let seq = super::super::blocked::solve_semiring::<MaxMin>(&prepared, 16);
        for threads in [2, 4] {
            assert_eq!(solve_semiring::<MaxMin>(&prepared, 16, threads), seq);
            assert_eq!(
                solve_paths_semiring::<MaxMin>(&prepared, 16, threads).dist,
                seq,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn profiled_solve_is_bitwise_identical() {
        let g = generators::erdos_renyi(96, 0.3, 71);
        for threads in [1, 2, 4] {
            let (dist, prof) = solve_profiled(&g, 32, threads);
            assert_eq!(dist, solve(&g, 32, threads), "threads={threads}");
            assert_eq!(prof.rounds, 3);
            assert!(prof.total_seconds() > 0.0);
        }
        // ragged n pads bitwise like the plain solver
        let ragged = generators::erdos_renyi(48, 0.4, 73);
        let (dist, _) = solve_profiled(&ragged, 32, 4);
        assert_eq!(dist, solve(&ragged, 32, 4));
    }

    #[test]
    fn paths_non_multiple_pads_bitwise_like_blocked() {
        let g = generators::erdos_renyi(48, 0.4, 43);
        // 48 % 32 != 0 → pads to 64; the banded solver on the padded graph
        // matches the sequential blocked path solver bit for bit (both
        // distances and successors)
        let r = solve_paths(&g, 32, 4);
        assert_eq!(r, super::super::blocked::solve_paths(&g, 32));
        // 0 threads → sequential blocked path solver
        let seq = solve_paths(&g, 16, 0);
        assert_eq!(seq, super::super::blocked::solve_paths(&g, 16));
    }
}

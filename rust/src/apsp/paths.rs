//! Shortest-path *reconstruction*: FW with a successor matrix.
//!
//! The paper (like most APSP kernels) computes distances only; downstream
//! users of a routing service almost always need the actual paths.  This
//! module holds the shared successor-matrix machinery: the direct-edge
//! initializer ([`init_succ`]), the reference solver ([`solve`], naive loop
//! order), and [`PathsResult`] with O(len) path extraction.
//!
//! The update rule every tier shares: whenever a relaxation improves
//! `dist[i][j]` via `dist[i][k] + dist[k][j]`, set
//! `succ[i][j] = succ[i][k]` — the first hop toward `j` is the first hop
//! toward the pivot `k`.  Blocked decompositions only change *where* the
//! `(i, k)` value lives (diagonal tile, column panel, detached super-tile),
//! never the rule, which is what lets successor tracking ride the fast
//! paths in [`super::blocked`], [`super::parallel`], and
//! [`crate::superblock`] unchanged; this solver is the reference those
//! tiers are differentially tested against (`rust/tests/conformance.rs`).

use super::semiring::Semiring;
use crate::graph::DistMatrix;

/// APSP result with path reconstruction support.
#[derive(Clone, Debug, PartialEq)]
pub struct PathsResult {
    pub dist: DistMatrix,
    /// `succ[i*n + j]` = next vertex after `i` on the shortest i→j path;
    /// `usize::MAX` when no path exists (or i == j).
    succ: Vec<usize>,
}

/// No-successor sentinel.
pub const NO_PATH: usize = usize::MAX;

/// Direct-edge successor initialization: `succ[i][j] = j` for every finite
/// off-diagonal edge, [`NO_PATH`] elsewhere.  Every successor-tracking
/// solver starts from this matrix.
pub fn init_succ(w: &DistMatrix) -> Vec<usize> {
    let n = w.n();
    let mut succ = vec![NO_PATH; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j && w.get(i, j).is_finite() {
                succ[i * n + j] = j; // direct edge
            }
        }
    }
    succ
}

/// Floyd-Warshall with successor tracking (naive loop order; the reference
/// implementation the fast tiers are tested against).
pub fn solve(w: &DistMatrix) -> PathsResult {
    let n = w.n();
    let mut dist = w.clone();
    let mut succ = init_succ(w);
    {
        let d = dist.as_mut_slice();
        for k in 0..n {
            for i in 0..n {
                let dik = d[i * n + k];
                if !dik.is_finite() || i == k {
                    continue;
                }
                for j in 0..n {
                    let cand = dik + d[k * n + j];
                    if cand < d[i * n + j] {
                        d[i * n + j] = cand;
                        succ[i * n + j] = succ[i * n + k];
                    }
                }
            }
        }
    }
    PathsResult { dist, succ }
}

/// Direct-edge successor initialization in a semiring's domain:
/// `succ[i][j] = j` wherever the off-diagonal entry is a live edge
/// (not `S::ZERO`).  At `MinPlus` this is exactly [`init_succ`].
pub fn init_succ_semiring<S: Semiring>(w: &DistMatrix) -> Vec<usize> {
    let n = w.n();
    let mut succ = vec![NO_PATH; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j && !S::is_zero(w.get(i, j)) {
                succ[i * n + j] = j; // direct edge
            }
        }
    }
    succ
}

/// Generic Floyd-Warshall with successor tracking — [`solve`] over any
/// [`Semiring`], sharing the strict-accept rule: a successor changes only
/// when [`Semiring::improves`] holds, so ties keep the earliest-pivot
/// witness in every instance.  The reference the generic fast tiers are
/// differentially tested against.  Expects the matrix in the semiring's
/// domain (`S::ONE` diagonal, `S::ZERO` absent).
pub fn solve_semiring<S: Semiring>(w: &DistMatrix) -> PathsResult {
    let n = w.n();
    let mut dist = w.clone();
    let mut succ = init_succ_semiring::<S>(w);
    {
        let d = dist.as_mut_slice();
        for k in 0..n {
            for i in 0..n {
                let dik = d[i * n + k];
                if S::is_zero(dik) || i == k {
                    continue;
                }
                for j in 0..n {
                    let cand = S::extend(dik, d[k * n + j]);
                    if S::improves(cand, d[i * n + j]) {
                        d[i * n + j] = cand;
                        succ[i * n + j] = succ[i * n + k];
                    }
                }
            }
        }
    }
    PathsResult { dist, succ }
}

impl PathsResult {
    /// Assemble a result from a distance closure and a successor matrix
    /// (`succ.len()` must be `n²`).  Used by the blocked/parallel/superblock
    /// path tiers and by the wire codec when a response carries successors.
    pub fn from_parts(dist: DistMatrix, succ: Vec<usize>) -> PathsResult {
        let n = dist.n();
        assert_eq!(succ.len(), n * n, "succ length {} != {n}²", succ.len());
        PathsResult { dist, succ }
    }

    pub fn n(&self) -> usize {
        self.dist.n()
    }

    /// Consume into `(dist, succ)` — lets the serving layer move both
    /// matrices into a response without an O(n²) copy.
    pub fn into_parts(self) -> (DistMatrix, Vec<usize>) {
        (self.dist, self.succ)
    }

    /// The raw successor matrix, row-major (`NO_PATH` = unreachable).
    pub fn succ(&self) -> &[usize] {
        &self.succ
    }

    /// Next hop on the shortest i→j path, or `NO_PATH`.
    pub fn succ_at(&self, i: usize, j: usize) -> usize {
        let n = self.n();
        debug_assert!(i < n && j < n);
        self.succ[i * n + j]
    }

    /// The vertex sequence of a shortest i→j path (inclusive of both
    /// endpoints), or `None` if unreachable.  `Some([i])` when `i == j`.
    pub fn path(&self, i: usize, j: usize) -> Option<Vec<usize>> {
        let n = self.n();
        assert!(i < n && j < n, "path({i}, {j}) out of range for n={n}");
        if i == j {
            return Some(vec![i]);
        }
        if self.succ[i * n + j] == NO_PATH {
            return None;
        }
        let mut path = vec![i];
        let mut cur = i;
        // a simple path visits ≤ n vertices; the guard catches corrupted
        // successor chains (e.g. from negative cycles) instead of spinning
        for _ in 0..n {
            cur = self.succ[cur * n + j];
            path.push(cur);
            if cur == j {
                return Some(path);
            }
        }
        None
    }

    /// Take the top-left `m × m` corner of both matrices — the inverse of
    /// solving a padded graph ([`DistMatrix::padded`]).  Padded vertices
    /// are unreachable (no edges in or out), so no successor surviving in
    /// the corner can reference one; the corner is a self-contained
    /// result.  Shared by every tier that pads non-tile-multiple sizes
    /// (blocked, parallel, superblock, and the engine's path fallback).
    pub fn truncated(&self, m: usize) -> PathsResult {
        let n = self.n();
        assert!(m <= n, "cannot truncate {n} up to {m}");
        let dist = self.dist.truncated(m);
        let mut succ = vec![NO_PATH; m * m];
        for i in 0..m {
            succ[i * m..(i + 1) * m].copy_from_slice(&self.succ[i * n..i * n + m]);
        }
        PathsResult { dist, succ }
    }

    /// Sum of edge weights along [`PathsResult::path`] in the *original*
    /// graph — used by tests to confirm path length equals reported distance.
    pub fn path_weight(&self, original: &DistMatrix, i: usize, j: usize) -> Option<f64> {
        let path = self.path(i, j)?;
        let mut total = 0f64;
        for pair in path.windows(2) {
            let w = original.get(pair[0], pair[1]);
            if !w.is_finite() {
                return None; // corrupt path: uses a non-edge
            }
            total += w as f64;
        }
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::naive;
    use crate::graph::{generators, DistMatrix};

    #[test]
    fn distances_match_naive() {
        let g = generators::erdos_renyi(64, 0.3, 51);
        let r = solve(&g);
        assert!(r.dist.allclose(&naive::solve(&g), 1e-5, 1e-6));
    }

    #[test]
    fn path_endpoints_and_weight() {
        let g = generators::grid(6, 9);
        let r = solve(&g);
        for i in [0, 7, 35] {
            for j in [0, 13, 20] {
                match r.path(i, j) {
                    Some(p) => {
                        assert_eq!(*p.first().unwrap(), i);
                        assert_eq!(*p.last().unwrap(), j);
                        let wt = r.path_weight(&g, i, j).unwrap();
                        let d = r.dist.get(i, j) as f64;
                        assert!((wt - d).abs() < 1e-4, "({i},{j}): {wt} vs {d}");
                    }
                    None => assert!(!r.dist.get(i, j).is_finite()),
                }
            }
        }
    }

    #[test]
    fn trivial_and_unreachable() {
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 2.0);
        let r = solve(&g);
        assert_eq!(r.path(0, 0), Some(vec![0]));
        assert_eq!(r.path(0, 1), Some(vec![0, 1]));
        assert_eq!(r.path(1, 0), None);
        assert_eq!(r.path(2, 1), None);
    }

    #[test]
    fn path_takes_shortcut() {
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 10.0);
        g.set(0, 2, 2.0);
        g.set(2, 1, 3.0);
        let r = solve(&g);
        assert_eq!(r.path(0, 1), Some(vec![0, 2, 1]));
    }

    #[test]
    fn ring_path_is_whole_ring() {
        let g = generators::ring(6);
        let r = solve(&g);
        assert_eq!(r.path(1, 0), Some(vec![1, 2, 3, 4, 5, 0]));
    }

    #[test]
    fn from_parts_roundtrips_solver_output() {
        let g = generators::grid(4, 3);
        let r = solve(&g);
        let rebuilt = PathsResult::from_parts(r.dist.clone(), r.succ().to_vec());
        assert_eq!(rebuilt, r);
        assert_eq!(rebuilt.succ_at(0, 0), NO_PATH);
    }

    #[test]
    #[should_panic(expected = "succ length")]
    fn from_parts_rejects_wrong_length() {
        let g = generators::ring(4);
        PathsResult::from_parts(g, vec![NO_PATH; 3]);
    }

    #[test]
    fn init_succ_marks_direct_edges_only() {
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 2.0);
        g.set(2, 0, 1.0);
        let succ = init_succ(&g);
        assert_eq!(succ[1], 1); // (0, 1): direct edge
        assert_eq!(succ[6], 0); // (2, 0): direct edge
        assert_eq!(succ[2], NO_PATH); // (0, 2): no edge
        assert_eq!(succ[4], NO_PATH); // (1, 1): diagonal
    }

    #[test]
    fn truncated_inverts_padding_bitwise() {
        // padded vertices are unreachable, so solving the padded graph and
        // cutting the corner is the solve of the original — same pivots in
        // the same order, identical accepts, for dist and succ alike
        let g = generators::erdos_renyi(12, 0.4, 77);
        let cut = solve(&g.padded(16)).truncated(12);
        assert_eq!(cut, solve(&g));
        // trivial cases
        let r = solve(&g);
        assert_eq!(r.truncated(12), r);
        assert_eq!(r.truncated(0).n(), 0);
    }

    #[test]
    fn generic_minplus_matches_specialized_exactly() {
        use crate::apsp::semiring::MinPlus;
        let g = generators::erdos_renyi(40, 0.3, 91);
        let spec = solve(&g);
        let gen = solve_semiring::<MinPlus>(&g);
        assert_eq!(spec, gen); // dist bitwise (PartialEq on f32) and succ
        assert_eq!(init_succ(&g), init_succ_semiring::<MinPlus>(&g));
    }

    #[test]
    fn generic_maxmin_successors_trace_the_widest_route() {
        use crate::apsp::semiring::MaxMin;
        let n = 3;
        let mut g = DistMatrix::unconnected(n);
        for i in 0..n {
            for j in 0..n {
                g.set(i, j, if i == j { crate::INF } else { 0.0 });
            }
        }
        g.set(0, 1, 2.0);
        g.set(0, 2, 8.0);
        g.set(2, 1, 5.0);
        let r = solve_semiring::<MaxMin>(&g);
        assert_eq!(r.dist.get(0, 1), 5.0);
        assert_eq!(r.path(0, 1), Some(vec![0, 2, 1])); // widest route detours
        assert_eq!(r.path(1, 2), None);
    }

    #[test]
    fn every_pair_consistent_on_random_graph() {
        let g = generators::erdos_renyi(32, 0.2, 53);
        let r = solve(&g);
        for i in 0..g.n() {
            for j in 0..g.n() {
                let d = r.dist.get(i, j);
                match r.path(i, j) {
                    Some(p) => {
                        assert!(d.is_finite());
                        // path must be simple (no repeated vertex)
                        let mut seen = p.clone();
                        seen.sort_unstable();
                        seen.dedup();
                        assert_eq!(seen.len(), p.len(), "non-simple path {p:?}");
                    }
                    None => assert!(!d.is_finite() || i == j),
                }
            }
        }
    }
}

//! Incremental APSP: apply edge-weight deltas to an existing closure.
//!
//! Real routing traffic is dominated by small weight changes against a
//! graph that has already been solved — congestion on a handful of road
//! segments, a link going down — not by fresh topologies.  Recomputing the
//! full Θ(n³) closure for a k-edge delta wastes a factor of ~n/k; this
//! module turns a cached `(dist, succ)` closure into the base state of a
//! dynamic-graph service:
//!
//! * **Decreases** (including edge insertions) run the classic O(n²)
//!   per-edge relaxation: for every pair, `d[i][j] ←
//!   min(d[i][j], d[i][u] + w + d[v][j])`, with the per-row prefix
//!   `d[i][u] + w` hoisted so the inner sweep is exactly
//!   [`kernel::relax_row`]'s shape.  One pass per edge is *exact*: absent
//!   negative cycles a shortest path crosses the changed edge at most
//!   once, so splitting at that edge enumerates every new candidate.
//! * **Increases** (including deletions) first detect the damage without
//!   touching a float: a stored pair (i, j) can only change if the stored
//!   successor walk i → … → j crosses a bumped edge, and walking the
//!   successor forest per target column costs O(n²) total (memoized).
//!   Untouched pairs keep their — still exact — closure values; touched
//!   pairs fall back to their mutated direct edge and are re-closed by a
//!   **bounded re-solve**: the full Floyd-Warshall pivot sweep restricted
//!   to the touched rows (O(n²·|rows|)).  The restriction is sound because
//!   every row containing a touched pair is in the sweep, so the standard
//!   FW induction closes (see DESIGN.md §Incremental tier for the
//!   argument).  When the touched-row count exceeds
//!   [`UpdateConfig::recompute_fraction`]·n, the bounded re-solve would
//!   approach Θ(n³) anyway and the batch falls back to a from-scratch
//!   [`parallel::solve_paths`].
//!
//! **Bitwise contract.**  On workloads whose path sums are exactly
//! representable in f32 (the dyadic-lattice family the update-conformance
//! suite generates), every value this module produces is *the* exact
//! shortest distance, so distances are bitwise-equal to a from-scratch
//! solve by any tier — that is what `tests/conformance.rs` pins.  At
//! arbitrary float weights the incremental candidates associate additions
//! differently than a from-scratch pivot order (`(d[i][u] + w) + d[v][j]`
//! vs the recompute's pivot-split sums), so agreement is to `allclose`
//! tolerance there, and successor matrices agree semantically (same
//! reachability, reconstructed walks of the same cost) rather than
//! literally — equal-cost ties may pick different first hops.
//!
//! The coordinator threads this end-to-end: an `"update"` request carries
//! a base-graph fingerprint plus an edge-delta list, the cache chains
//! mutated fingerprints (`coordinator::cache`), and a chain-length cap
//! forces periodic re-baselining through a full solve.

use std::collections::{HashMap, HashSet};

use super::kernel;
use super::parallel;
use super::paths::{PathsResult, NO_PATH};
use crate::graph::DistMatrix;
use crate::Dist;

/// One edge-weight update: set `w(src, dst)` to `weight`.  `+inf` removes
/// the edge; a weight below the current one is a *decrease* (insertions
/// included), above it an *increase* (deletions included).  Self-loops are
/// rejected — the diagonal is pinned to zero across the stack.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EdgeUpdate {
    pub src: usize,
    pub dst: usize,
    pub weight: Dist,
}

/// Tuning knobs for [`update_paths`] / [`update_dist`].
#[derive(Clone, Copy, Debug)]
pub struct UpdateConfig {
    /// Fraction of rows the increase phase may touch before the bounded
    /// re-solve loses to a full recompute.  `0.0` forces a recompute for
    /// any increase that lands on a stored path; `1.0` never recomputes.
    pub recompute_fraction: f64,
    /// Tile size for full recomputes ([`parallel::solve_paths`]).
    pub tile: usize,
    /// Thread count for full recomputes; 0 = one per core.  Thread count
    /// never changes bits (pinned by the parallel solver's own tests).
    pub threads: usize,
}

impl Default for UpdateConfig {
    fn default() -> Self {
        UpdateConfig {
            recompute_fraction: 0.25,
            tile: crate::DEFAULT_TILE,
            threads: 0,
        }
    }
}

impl UpdateConfig {
    fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
        }
    }
}

/// How a batch was actually served (surfaced to metrics and benches).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UpdateStats {
    /// Effective decreases after last-write-wins normalization.
    pub decreases: usize,
    /// Effective increases after normalization.
    pub increases: usize,
    /// Updates whose net weight equals the current one.
    pub noops: usize,
    /// Rows the increase phase re-relaxed (0 = no stored path was hit).
    pub touched_rows: usize,
    /// The batch fell back to a from-scratch `parallel` solve.
    pub recomputed: bool,
}

/// Reject updates the rest of the stack's invariants cannot absorb —
/// mirrors [`DistMatrix::validate`] (no NaN, no `-inf`, no `-0.0`) plus
/// the index/diagonal checks.
fn validate_update(n: usize, u: &EdgeUpdate) -> Result<(), String> {
    if u.src >= n || u.dst >= n {
        return Err(format!(
            "update ({} -> {}) endpoint out of range for n={n}",
            u.src, u.dst
        ));
    }
    if u.src == u.dst {
        return Err(format!(
            "update ({} -> {}) is a self-loop (the diagonal is pinned to 0)",
            u.src, u.dst
        ));
    }
    if u.weight.is_nan() {
        return Err(format!("update ({} -> {}) weight is NaN", u.src, u.dst));
    }
    if u.weight == f32::NEG_INFINITY {
        return Err(format!("update ({} -> {}) weight is -inf", u.src, u.dst));
    }
    if u.weight == 0.0 && u.weight.is_sign_negative() {
        return Err(format!(
            "update ({} -> {}) weight is -0.0 (the bitwise contracts exclude it)",
            u.src, u.dst
        ));
    }
    Ok(())
}

/// Net effect of a batch — the *last* write to each `(src, dst)` wins,
/// preserving first-seen order — classified against the current graph.
/// Returns `(decreases, increases, noop count)`.
fn normalize(
    graph: &DistMatrix,
    updates: &[EdgeUpdate],
) -> Result<(Vec<EdgeUpdate>, Vec<EdgeUpdate>, usize), String> {
    let n = graph.n();
    let mut net: Vec<EdgeUpdate> = Vec::with_capacity(updates.len());
    let mut index: HashMap<(usize, usize), usize> = HashMap::new();
    for u in updates {
        validate_update(n, u)?;
        match index.get(&(u.src, u.dst)) {
            Some(&i) => net[i] = *u,
            None => {
                index.insert((u.src, u.dst), net.len());
                net.push(*u);
            }
        }
    }
    let mut decreases = Vec::new();
    let mut increases = Vec::new();
    let mut noops = 0;
    for u in net {
        let old = graph.get(u.src, u.dst);
        // NaN is rejected above, so partial_cmp is total here (and +inf
        // compares equal to +inf: re-deleting a missing edge is a no-op)
        match u.weight.partial_cmp(&old) {
            Some(std::cmp::Ordering::Equal) => noops += 1,
            Some(std::cmp::Ordering::Less) => decreases.push(u),
            _ => increases.push(u),
        }
    }
    Ok((decreases, increases, noops))
}

/// Validate a batch against a graph size without applying it.  The wire
/// client runs this before encoding: the codec has no rendering for NaN
/// or `-inf` (JSON `null` means "+inf, delete"), so malformed weights
/// must fail loudly client-side instead of silently mutating into
/// deletions on the wire.
pub fn validate_batch(n: usize, updates: &[EdgeUpdate]) -> Result<(), String> {
    for u in updates {
        validate_update(n, u)?;
    }
    Ok(())
}

/// The graph after applying `updates` (last write per edge wins).  Pure —
/// the coordinator fingerprints this to key the chained cache entry, and
/// clients use it to fall back to a full solve on a cache miss.
pub fn mutated(graph: &DistMatrix, updates: &[EdgeUpdate]) -> Result<DistMatrix, String> {
    let n = graph.n();
    let mut out = graph.clone();
    for u in updates {
        validate_update(n, u)?;
        out.set(u.src, u.dst, u.weight);
    }
    Ok(out)
}

/// Whether the batch's net effect contains at least one increase — the
/// coordinator uses this to route increase batches against successor-less
/// cache entries (johnson/device closures) to a full solve instead.
pub fn has_effective_increase(
    graph: &DistMatrix,
    updates: &[EdgeUpdate],
) -> Result<bool, String> {
    let (_, increases, _) = normalize(graph, updates)?;
    Ok(!increases.is_empty())
}

/// Apply an update batch to a `(dist, succ)` closure of `graph`.
///
/// `closure` must be a valid APSP closure of `graph` (the coordinator
/// guarantees this by construction: entries are only cached by solves and
/// by prior updates).  Returns the closure of the mutated graph and the
/// serving stats, or an error if the batch is malformed or creates a
/// negative cycle.
pub fn update_paths(
    graph: &DistMatrix,
    closure: &PathsResult,
    updates: &[EdgeUpdate],
    cfg: &UpdateConfig,
) -> Result<(PathsResult, UpdateStats), String> {
    let n = graph.n();
    if closure.n() != n {
        return Err(format!("closure size {} != graph size {n}", closure.n()));
    }
    let (decreases, increases, noops) = normalize(graph, updates)?;
    let mut stats = UpdateStats {
        decreases: decreases.len(),
        increases: increases.len(),
        noops,
        ..UpdateStats::default()
    };
    if decreases.is_empty() && increases.is_empty() {
        return Ok((closure.clone(), stats));
    }

    // increases first: the decrease relaxation is only exact against an
    // exact closure of the graph it relaxes
    let mut g1 = graph.clone();
    for u in &increases {
        g1.set(u.src, u.dst, u.weight);
    }
    let (mut dist, mut succ) = if increases.is_empty() {
        closure.clone().into_parts()
    } else {
        match increase_phase(&g1, closure, &increases, cfg) {
            IncreaseOutcome::Unchanged => closure.clone().into_parts(),
            IncreaseOutcome::Bounded { dist, succ, rows } => {
                stats.touched_rows = rows;
                (dist, succ)
            }
            IncreaseOutcome::Recompute => {
                stats.recomputed = true;
                let mut g2 = g1;
                for u in &decreases {
                    g2.set(u.src, u.dst, u.weight);
                }
                let r = parallel::solve_paths(&g2, cfg.tile, cfg.resolved_threads());
                return Ok((r, stats));
            }
        }
    };

    {
        let d = dist.as_mut_slice();
        for u in &decreases {
            relax_decrease_succ(d, &mut succ, n, u)?;
        }
    }
    Ok((PathsResult::from_parts(dist, succ), stats))
}

/// Distance-only twin of [`update_paths`] for closures cached without a
/// successor matrix.  Decrease batches apply the same relaxation (the
/// branchless [`kernel::relax_row`] — value-identical to the branchy
/// accept); increase detection needs the stored successor forest, so any
/// effective increase falls back to a full recompute here.  The
/// coordinator routes that case through its own solve path instead, so
/// device-scale recomputes still reach the device tier.
pub fn update_dist(
    graph: &DistMatrix,
    dist: &DistMatrix,
    updates: &[EdgeUpdate],
    cfg: &UpdateConfig,
) -> Result<(DistMatrix, UpdateStats), String> {
    let n = graph.n();
    if dist.n() != n {
        return Err(format!("closure size {} != graph size {n}", dist.n()));
    }
    let (decreases, increases, noops) = normalize(graph, updates)?;
    let mut stats = UpdateStats {
        decreases: decreases.len(),
        increases: increases.len(),
        noops,
        ..UpdateStats::default()
    };
    if !increases.is_empty() {
        stats.recomputed = true;
        let mut g2 = graph.clone();
        for u in increases.iter().chain(&decreases) {
            g2.set(u.src, u.dst, u.weight);
        }
        return Ok((parallel::solve(&g2, cfg.tile, cfg.resolved_threads()), stats));
    }
    if decreases.is_empty() {
        return Ok((dist.clone(), stats));
    }
    let mut out = dist.clone();
    {
        let d = out.as_mut_slice();
        for u in &decreases {
            relax_decrease(d, n, u)?;
        }
    }
    Ok((out, stats))
}

/// A decrease can only create (never remove) negative cycles; surface them
/// before the corrupt closure escapes.  O(n) diagonal scan per edge.
fn check_no_negative_cycle(dist: &[f32], n: usize, up: &EdgeUpdate) -> Result<(), String> {
    for i in 0..n {
        if dist[i * n + i] < 0.0 {
            return Err(format!(
                "update ({} -> {}, {}) creates a negative cycle through vertex {i}",
                up.src, up.dst, up.weight
            ));
        }
    }
    Ok(())
}

/// Classic single-edge decrease relaxation with successor tracking:
/// `d[i][j] ← min(d[i][j], (d[i][u] + w) + d[v][j])`, copying the first
/// hop toward `u` (from `u` itself: the new edge's head `v`) on accept —
/// the same rule every tier shares (`apsp::paths` module docs).
fn relax_decrease_succ(
    dist: &mut [f32],
    succ: &mut [usize],
    n: usize,
    up: &EdgeUpdate,
) -> Result<(), String> {
    let (u, v, w) = (up.src, up.dst, up.weight);
    if !w.is_finite() {
        return Ok(()); // defensive: a decrease is always finite
    }
    for i in 0..n {
        let p = if i == u {
            w
        } else {
            let diu = dist[i * n + u];
            if !diu.is_finite() {
                continue;
            }
            diu + w
        };
        let s = if i == u { v } else { succ[i * n + u] };
        if i == v {
            // the row being written is also the row panel being read; each
            // cell's candidate uses only that cell's own pre-update value,
            // so a plain sweep is safe (and the classic formula's order)
            for j in 0..n {
                let cur = dist[v * n + j];
                let cand = p + cur;
                if cand < cur {
                    dist[v * n + j] = cand;
                    succ[v * n + j] = s;
                }
            }
        } else {
            let base = i * n;
            let (out, row_v) = kernel::row_pair_mut(dist, n, i, v, 0, n);
            for j in 0..n {
                let cand = p + row_v[j];
                if cand < out[j] {
                    out[j] = cand;
                    succ[base + j] = s;
                }
            }
        }
    }
    check_no_negative_cycle(dist, n, up)
}

/// Distance-only decrease relaxation — the same sweep through the shared
/// branchless kernel helper (bitwise-identical values to the branchy
/// accept; see `kernel`'s module docs).
fn relax_decrease(dist: &mut [f32], n: usize, up: &EdgeUpdate) -> Result<(), String> {
    let (u, v, w) = (up.src, up.dst, up.weight);
    if !w.is_finite() {
        return Ok(());
    }
    for i in 0..n {
        let p = if i == u {
            w
        } else {
            let diu = dist[i * n + u];
            if !diu.is_finite() {
                continue;
            }
            diu + w
        };
        if i == v {
            for j in 0..n {
                let cur = dist[v * n + j];
                dist[v * n + j] = cur.min(p + cur);
            }
        } else {
            let (out, row_v) = kernel::row_pair_mut(dist, n, i, v, 0, n);
            kernel::relax_row(out, row_v, p);
        }
    }
    check_no_negative_cycle(dist, n, up)
}

// ------------------------------------------------------- increase phase --

enum IncreaseOutcome {
    /// No stored path crosses a bumped edge: the closure is untouched.
    Unchanged,
    /// Touched pairs re-closed by the row-restricted pivot sweep.
    Bounded {
        dist: DistMatrix,
        succ: Vec<usize>,
        rows: usize,
    },
    /// Touched-row count exceeded the threshold; recompute from scratch.
    Recompute,
}

const UNKNOWN: u8 = 0;
const CLEAN: u8 = 1;
const HIT: u8 = 2;
const PENDING: u8 = 3;

/// For target column `j`, mark every source `i` whose *stored* successor
/// walk i → … → j crosses a bumped edge.  Float-free and memoized: the
/// successor pointers toward a fixed target form a forest, so each vertex
/// is resolved once — O(n) per column amortized.  A cycle in the stored
/// forest (corrupt closure) marks its members conservatively.
fn mark_column(
    succ: &[usize],
    n: usize,
    j: usize,
    bumped: &HashSet<(usize, usize)>,
    state: &mut [u8],
    chain: &mut Vec<usize>,
) {
    state.fill(UNKNOWN);
    state[j] = CLEAN;
    for start in 0..n {
        if state[start] != UNKNOWN {
            continue;
        }
        chain.clear();
        let mut cur = start;
        let verdict = loop {
            match state[cur] {
                CLEAN => break CLEAN,
                HIT => break HIT,
                PENDING => break HIT, // cycle: be conservative
                _ => {}
            }
            let next = succ[cur * n + j];
            if next == NO_PATH {
                state[cur] = CLEAN; // unreachable: no stored path to damage
                break CLEAN;
            }
            if bumped.contains(&(cur, next)) {
                state[cur] = HIT;
                break HIT;
            }
            state[cur] = PENDING;
            chain.push(cur);
            cur = next;
        };
        for &x in chain.iter() {
            state[x] = verdict;
        }
    }
}

fn increase_phase(
    g1: &DistMatrix,
    closure: &PathsResult,
    increases: &[EdgeUpdate],
    cfg: &UpdateConfig,
) -> IncreaseOutcome {
    let n = g1.n();
    let bumped: HashSet<(usize, usize)> =
        increases.iter().map(|u| (u.src, u.dst)).collect();
    let succ_old = closure.succ();
    let mut affected = vec![false; n * n];
    let mut row_hit = vec![false; n];
    let mut state = vec![UNKNOWN; n];
    let mut chain = Vec::new();
    let mut any = false;
    for j in 0..n {
        mark_column(succ_old, n, j, &bumped, &mut state, &mut chain);
        for i in 0..n {
            if state[i] == HIT {
                affected[i * n + j] = true;
                row_hit[i] = true;
                any = true;
            }
        }
    }
    if !any {
        return IncreaseOutcome::Unchanged;
    }
    let rows: Vec<usize> = (0..n).filter(|&i| row_hit[i]).collect();
    if (rows.len() as f64) > cfg.recompute_fraction * n as f64 {
        return IncreaseOutcome::Recompute;
    }

    // seed: touched pairs drop back to their (mutated) direct edge; every
    // untouched entry keeps its — still exact — closure value (increases
    // cannot improve a distance, and an untouched pair's stored path
    // survives at unchanged cost)
    let mut dist = closure.dist.clone();
    let mut succ = succ_old.to_vec();
    let d = dist.as_mut_slice();
    for &i in &rows {
        for j in 0..n {
            if affected[i * n + j] {
                let w = g1.get(i, j);
                d[i * n + j] = w;
                succ[i * n + j] = if w.is_finite() { j } else { NO_PATH };
            }
        }
    }
    // bounded re-solve: the full pivot sweep, restricted to touched rows.
    // Sound because every row holding a touched pair is swept: for a
    // touched (i, j), the FW induction needs d[i][k] (row i — swept) and
    // d[k][j] (exact already if (k, j) untouched; row k swept otherwise).
    for k in 0..n {
        for &i in &rows {
            if i == k {
                continue;
            }
            let wik = d[i * n + k];
            if !wik.is_finite() {
                continue;
            }
            let sik = succ[i * n + k];
            let base = i * n;
            let (out, row_k) = kernel::row_pair_mut(d, n, i, k, 0, n);
            for j in 0..n {
                let cand = wik + row_k[j];
                if cand < out[j] {
                    out[j] = cand;
                    succ[base + j] = sik;
                }
            }
        }
    }
    IncreaseOutcome::Bounded {
        dist,
        succ,
        rows: rows.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::paths;
    use crate::graph::generators;
    use crate::INF;

    fn cfg(tile: usize) -> UpdateConfig {
        UpdateConfig {
            tile,
            threads: 2,
            ..UpdateConfig::default()
        }
    }

    fn recompute(g: &DistMatrix, tile: usize) -> PathsResult {
        parallel::solve_paths(g, tile, 2)
    }

    /// Exact-lattice ER graph: weights are multiples of 1/16 in (0, 128],
    /// so every path sum is exactly representable in f32 and any correct
    /// solver returns identical bits (the module's bitwise contract).
    fn lattice_graph(n: usize, p: f64, seed: u64) -> DistMatrix {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut g = DistMatrix::unconnected(n);
        for i in 0..n {
            for j in 0..n {
                if i != j && rng.next_f64() < p {
                    g.set(i, j, (rng.range(1, 2049) as f32) * 0.0625);
                }
            }
        }
        g
    }

    #[test]
    fn decrease_matches_recompute_bitwise_on_lattice() {
        let g = lattice_graph(24, 0.2, 11);
        let base = recompute(&g, 8);
        // the minimum lattice weight can never be an *increase* (any
        // existing weight is ≥ it; equality is a no-op)
        let batch = vec![
            EdgeUpdate { src: 3, dst: 17, weight: 0.0625 },
            EdgeUpdate { src: 5, dst: 9, weight: 0.0625 },
        ];
        let (got, stats) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        assert!(!stats.recomputed);
        assert_eq!(stats.increases, 0);
        let g2 = mutated(&g, &batch).unwrap();
        assert_eq!(got.dist, recompute(&g2, 8).dist);
    }

    #[test]
    fn increase_matches_recompute_bitwise_on_lattice() {
        let g = lattice_graph(20, 0.35, 13);
        let base = recompute(&g, 8);
        // bump / delete edges that exist (guaranteed effective increases
        // when finite); deleting forces affected-pair detection
        let mut batch = Vec::new();
        'outer: for i in 0..g.n() {
            for j in 0..g.n() {
                if i != j && g.get(i, j).is_finite() {
                    batch.push(EdgeUpdate { src: i, dst: j, weight: INF });
                    if batch.len() == 2 {
                        break 'outer;
                    }
                }
            }
        }
        assert_eq!(batch.len(), 2, "graph dense enough for the test");
        let (got, _stats) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        let g2 = mutated(&g, &batch).unwrap();
        let expect = recompute(&g2, 8);
        assert_eq!(got.dist, expect.dist);
        // reachability must agree exactly too
        for i in 0..g.n() {
            for j in 0..g.n() {
                assert_eq!(
                    got.succ_at(i, j) == NO_PATH,
                    expect.succ_at(i, j) == NO_PATH,
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn noop_and_duplicate_updates() {
        let g = lattice_graph(12, 0.4, 17);
        let base = recompute(&g, 8);
        // find one existing edge
        let (u, v) = (0..g.n())
            .flat_map(|i| (0..g.n()).map(move |j| (i, j)))
            .find(|&(i, j)| i != j && g.get(i, j).is_finite())
            .expect("an edge");
        let w = g.get(u, v);
        // a no-op plus a duplicate pair whose last write restores the
        // original weight: net batch is empty
        let batch = vec![
            EdgeUpdate { src: u, dst: v, weight: w },
            EdgeUpdate { src: u, dst: v, weight: w * 0.5 },
            EdgeUpdate { src: u, dst: v, weight: w },
        ];
        let (got, stats) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        assert_eq!(stats.noops, 1);
        assert_eq!(stats.decreases + stats.increases, 0);
        assert_eq!(got, base);
        assert_eq!(mutated(&g, &batch).unwrap(), g);
    }

    #[test]
    fn duplicate_last_write_wins() {
        let g = lattice_graph(10, 0.5, 19);
        let base = recompute(&g, 8);
        let batch = vec![
            EdgeUpdate { src: 1, dst: 2, weight: 4.0 },
            EdgeUpdate { src: 1, dst: 2, weight: 0.25 },
        ];
        let (got, _) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        let g2 = mutated(&g, &batch).unwrap();
        assert_eq!(g2.get(1, 2), 0.25, "last write wins");
        assert_eq!(got.dist, recompute(&g2, 8).dist);
    }

    #[test]
    fn zero_threshold_forces_recompute_and_stays_bitwise() {
        let g = lattice_graph(16, 0.4, 23);
        let base = recompute(&g, 8);
        let (u, v) = (0..g.n())
            .flat_map(|i| (0..g.n()).map(move |j| (i, j)))
            .find(|&(i, j)| i != j && g.get(i, j).is_finite())
            .expect("an edge");
        let batch = vec![EdgeUpdate { src: u, dst: v, weight: INF }];
        let mut c = cfg(8);
        c.recompute_fraction = 0.0;
        let (got, stats) = update_paths(&g, &base, &batch, &c).unwrap();
        // the (u, v) pair's own stored walk starts with the deleted edge
        // whenever that edge is the stored optimum; either way the deleted
        // edge is on *some* stored walk here, so the zero threshold must
        // trip if anything was touched
        let g2 = mutated(&g, &batch).unwrap();
        let expect = recompute(&g2, 8);
        if stats.recomputed {
            // identical call → identical bits, succ included
            assert_eq!(got, expect);
        } else {
            assert_eq!(got.dist, expect.dist);
        }
    }

    #[test]
    fn dist_only_twin_matches_paths_distances() {
        let g = lattice_graph(18, 0.3, 29);
        let base = recompute(&g, 8);
        // minimum lattice weight → never an increase (see above)
        let batch = vec![
            EdgeUpdate { src: 2, dst: 7, weight: 0.0625 },
            EdgeUpdate { src: 11, dst: 4, weight: 0.0625 },
        ];
        let (with_succ, _) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        let (dist_only, stats) = update_dist(&g, &base.dist, &batch, &cfg(8)).unwrap();
        assert!(!stats.recomputed, "decrease-only stays incremental");
        assert_eq!(dist_only, with_succ.dist);
    }

    #[test]
    fn dist_only_increase_recomputes() {
        let g = lattice_graph(14, 0.4, 31);
        let base = recompute(&g, 8);
        let (u, v) = (0..g.n())
            .flat_map(|i| (0..g.n()).map(move |j| (i, j)))
            .find(|&(i, j)| i != j && g.get(i, j).is_finite())
            .expect("an edge");
        let batch = vec![EdgeUpdate { src: u, dst: v, weight: INF }];
        let (dist, stats) = update_dist(&g, &base.dist, &batch, &cfg(8)).unwrap();
        assert!(stats.recomputed, "no successor forest → full recompute");
        let g2 = mutated(&g, &batch).unwrap();
        assert_eq!(dist, parallel::solve(&g2, 8, 2));
    }

    #[test]
    fn increase_of_unused_edge_is_unchanged() {
        // a parallel heavier edge next to a lighter one: bumping the heavy
        // edge can never touch a stored path
        let mut g = DistMatrix::unconnected(4);
        g.set(0, 1, 1.0);
        g.set(0, 2, 8.0);
        g.set(1, 2, 1.0);
        g.set(2, 3, 1.0);
        let base = paths::solve(&g);
        let batch = vec![EdgeUpdate { src: 0, dst: 2, weight: 9.0 }];
        let (got, stats) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        assert_eq!(stats.touched_rows, 0);
        assert!(!stats.recomputed);
        assert_eq!(got.dist, base.dist);
        assert_eq!(got.succ(), base.succ());
    }

    #[test]
    fn deletion_disconnects() {
        // 0 → 1 → 2 is the only route; deleting (1, 2) must sever 0→2 and
        // 1→2 in both matrices
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 1.0);
        g.set(1, 2, 1.0);
        let base = paths::solve(&g);
        let batch = vec![EdgeUpdate { src: 1, dst: 2, weight: INF }];
        // rows {0, 1} are touched — beyond the default quarter-of-n
        // threshold at n=3, so pin the *bounded* path explicitly
        let mut c = cfg(8);
        c.recompute_fraction = 1.0;
        let (got, stats) = update_paths(&g, &base, &batch, &c).unwrap();
        assert!(!stats.recomputed);
        assert!(stats.touched_rows >= 2);
        assert!(!got.dist.get(0, 2).is_finite());
        assert!(!got.dist.get(1, 2).is_finite());
        assert_eq!(got.succ_at(0, 2), NO_PATH);
        assert_eq!(got.succ_at(1, 2), NO_PATH);
        assert_eq!(got.dist.get(0, 1), 1.0);
        assert_eq!(got.path(0, 1), Some(vec![0, 1]));
    }

    #[test]
    fn insertion_creates_path_and_successors() {
        let mut g = DistMatrix::unconnected(4);
        g.set(0, 1, 2.0);
        g.set(2, 3, 2.0);
        let base = paths::solve(&g);
        assert!(!base.dist.get(0, 3).is_finite());
        let batch = vec![EdgeUpdate { src: 1, dst: 2, weight: 1.0 }];
        let (got, _) = update_paths(&g, &base, &batch, &cfg(8)).unwrap();
        assert_eq!(got.dist.get(0, 3), 5.0);
        assert_eq!(got.path(0, 3), Some(vec![0, 1, 2, 3]));
        let g2 = mutated(&g, &batch).unwrap();
        assert_eq!(got.dist, paths::solve(&g2).dist);
    }

    #[test]
    fn mixed_batch_on_random_floats_is_close_and_valid() {
        // arbitrary float weights: the bitwise contract does not apply
        // (association differs); agreement is to tolerance, paths valid
        let g = generators::erdos_renyi_weighted(28, 0.25, 0.1, 10.0, 37);
        let base = recompute(&g, 16);
        let mut batch = vec![
            EdgeUpdate { src: 1, dst: 20, weight: 0.05 }, // likely decrease/insert
            EdgeUpdate { src: 9, dst: 3, weight: 0.07 },
        ];
        if let Some((u, v)) = (0..g.n())
            .flat_map(|i| (0..g.n()).map(move |j| (i, j)))
            .find(|&(i, j)| {
                i != j && g.get(i, j).is_finite() && (i, j) != (1, 20) && (i, j) != (9, 3)
            })
        {
            batch.push(EdgeUpdate { src: u, dst: v, weight: INF }); // deletion
        }
        let (got, _) = update_paths(&g, &base, &batch, &cfg(16)).unwrap();
        let g2 = mutated(&g, &batch).unwrap();
        let expect = recompute(&g2, 16);
        assert!(
            got.dist.allclose(&expect.dist, 1e-4, 1e-4),
            "diverges by {}",
            got.dist.max_abs_diff(&expect.dist)
        );
        // every reconstructed walk is a real edge walk of the mutated graph
        for i in 0..g2.n() {
            for j in 0..g2.n() {
                if i == j {
                    continue;
                }
                match got.path(i, j) {
                    Some(_) => {
                        let w = got.path_weight(&g2, i, j).expect("valid walk");
                        let d = got.dist.get(i, j) as f64;
                        assert!((w - d).abs() < 1e-3 + 1e-4 * d.abs(), "({i},{j})");
                    }
                    None => assert!(!got.dist.get(i, j).is_finite()),
                }
            }
        }
    }

    #[test]
    fn negative_cycle_is_reported() {
        let mut g = DistMatrix::unconnected(3);
        g.set(0, 1, 1.0);
        g.set(1, 0, 1.0);
        let base = paths::solve(&g);
        let batch = vec![EdgeUpdate { src: 0, dst: 1, weight: -2.0 }];
        let err = update_paths(&g, &base, &batch, &cfg(8)).unwrap_err();
        assert!(err.contains("negative cycle"), "{err}");
    }

    #[test]
    fn malformed_updates_rejected() {
        let g = DistMatrix::unconnected(4);
        let base = paths::solve(&g);
        for (bad, needle) in [
            (EdgeUpdate { src: 0, dst: 9, weight: 1.0 }, "out of range"),
            (EdgeUpdate { src: 2, dst: 2, weight: 1.0 }, "self-loop"),
            (EdgeUpdate { src: 0, dst: 1, weight: f32::NAN }, "NaN"),
            (EdgeUpdate { src: 0, dst: 1, weight: f32::NEG_INFINITY }, "-inf"),
            (EdgeUpdate { src: 0, dst: 1, weight: -0.0 }, "-0.0"),
        ] {
            let err = update_paths(&g, &base, &[bad], &cfg(8)).unwrap_err();
            assert!(err.contains(needle), "{err:?} missing {needle:?}");
            assert!(mutated(&g, &[bad]).is_err());
            assert!(validate_batch(g.n(), &[bad]).is_err());
        }
        assert!(validate_batch(4, &[EdgeUpdate { src: 0, dst: 1, weight: 1.0 }]).is_ok());
    }

    #[test]
    fn empty_batch_returns_base_unchanged() {
        let g = lattice_graph(9, 0.4, 41);
        let base = recompute(&g, 8);
        let (got, stats) = update_paths(&g, &base, &[], &cfg(8)).unwrap();
        assert_eq!(got, base);
        assert_eq!(stats, UpdateStats::default());
        let (d, _) = update_dist(&g, &base.dist, &[], &cfg(8)).unwrap();
        assert_eq!(d, base.dist);
    }
}

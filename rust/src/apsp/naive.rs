//! The textbook Floyd-Warshall triple loop — the paper's "CPU"
//! implementation (Table 1, column 1; footnote 1 derives its time constant
//! of ≈1.2·10⁻¹¹ s/task on the authors' Phenom).
//!
//! Kept deliberately simple: this is both the baseline whose constant we
//! re-measure (EXPERIMENTS.md E7) and the most trustworthy oracle.

use super::semiring::Semiring;
use crate::graph::DistMatrix;

/// In-place Floyd-Warshall over `w` (paper Fig. 1).
pub fn solve_in_place(w: &mut DistMatrix) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in 0..n {
        for i in 0..n {
            let wik = data[i * n + k];
            if !wik.is_finite() {
                continue; // no i→k path: row k cannot improve row i this round
            }
            // hoisting row pointers keeps the inner loop at two loads + min
            let (row_k, row_i) = if i < k {
                let (lo, hi) = data.split_at_mut(k * n);
                (&hi[..n], &mut lo[i * n..i * n + n])
            } else if i > k {
                let (lo, hi) = data.split_at_mut(i * n);
                (&lo[k * n..k * n + n], &mut hi[..n])
            } else {
                continue; // i == k: w[k][j] <- min(w[k][j], w[k][k] + w[k][j]) is a no-op
            };
            // conditional store: most relaxations don't improve, so
            // skipping the store saves write bandwidth on full rows —
            // measured faster than branchless min here (the tiled solvers
            // prefer branchless; see blocked.rs)
            for j in 0..n {
                let cand = wik + row_k[j];
                if cand < row_i[j] {
                    row_i[j] = cand;
                }
            }
        }
    }
}

/// Functional wrapper: clone, solve, return.
pub fn solve(w: &DistMatrix) -> DistMatrix {
    let mut out = w.clone();
    solve_in_place(&mut out);
    out
}

/// In-place generic Floyd-Warshall: the triple loop of [`solve_in_place`]
/// with `(min, +, <, is_finite)` replaced by the [`Semiring`] hooks.  The
/// most trustworthy oracle for the non-shortest objectives, exactly as the
/// specialized loop is for `(min, +)`: these semirings are selection-only
/// (`⊕`/`⊗` return an operand, never a rounded sum), so every tier is
/// pinned against this loop with exact `==` in `tests/conformance.rs`.
///
/// Expects the matrix in the semiring's domain — `S::ONE` diagonal,
/// `S::ZERO` for absent edges (what `Objective::prepare` produces).
pub fn solve_in_place_semiring<S: Semiring>(w: &mut DistMatrix) {
    let n = w.n();
    let data = w.as_mut_slice();
    for k in 0..n {
        for i in 0..n {
            let wik = data[i * n + k];
            if S::is_zero(wik) {
                continue; // no i→k path: row k cannot improve row i this round
            }
            let (row_k, row_i) = if i < k {
                let (lo, hi) = data.split_at_mut(k * n);
                (&hi[..n], &mut lo[i * n..i * n + n])
            } else if i > k {
                let (lo, hi) = data.split_at_mut(i * n);
                (&lo[k * n..k * n + n], &mut hi[..n])
            } else {
                continue; // i == k: ⊗ by the ONE diagonal is a no-op
            };
            for j in 0..n {
                let cand = S::extend(wik, row_k[j]);
                if S::improves(cand, row_i[j]) {
                    row_i[j] = cand;
                }
            }
        }
    }
}

/// Functional wrapper over [`solve_in_place_semiring`].
pub fn solve_semiring<S: Semiring>(w: &DistMatrix) -> DistMatrix {
    let mut out = w.clone();
    solve_in_place_semiring::<S>(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, DistMatrix};
    use crate::INF;

    #[test]
    fn triangle_shortcut() {
        let mut m = DistMatrix::unconnected(3);
        m.set(0, 1, 10.0);
        m.set(0, 2, 2.0);
        m.set(2, 1, 3.0);
        let d = solve(&m);
        assert_eq!(d.get(0, 1), 5.0);
        assert_eq!(d.get(0, 2), 2.0);
    }

    #[test]
    fn ring_distances() {
        let d = solve(&generators::ring(10));
        for i in 0..10 {
            for j in 0..10 {
                let expect = ((j + 10 - i) % 10) as f32;
                assert_eq!(d.get(i, j), expect, "({i},{j})");
            }
        }
    }

    #[test]
    fn disconnected_stays_inf() {
        let mut m = DistMatrix::unconnected(4);
        m.set(0, 1, 1.0);
        let d = solve(&m);
        assert_eq!(d.get(0, 1), 1.0);
        assert_eq!(d.get(1, 0), INF);
        assert_eq!(d.get(2, 3), INF);
    }

    #[test]
    fn negative_edges_no_cycle() {
        let mut m = DistMatrix::unconnected(3);
        m.set(0, 1, -2.0);
        m.set(1, 2, 4.0);
        m.set(2, 0, 1.0);
        let d = solve(&m);
        assert_eq!(d.get(0, 2), 2.0);
        assert_eq!(d.get(2, 1), -1.0);
    }

    #[test]
    fn zero_and_one_vertex() {
        let d0 = solve(&DistMatrix::unconnected(0));
        assert_eq!(d0.n(), 0);
        let d1 = solve(&DistMatrix::unconnected(1));
        assert_eq!(d1.get(0, 0), 0.0);
    }

    #[test]
    fn generic_minplus_is_bitwise_the_specialized_loop() {
        use crate::apsp::semiring::MinPlus;
        let g = generators::erdos_renyi(40, 0.3, 23);
        let spec = solve(&g);
        let gen = solve_semiring::<MinPlus>(&g);
        assert!(spec
            .as_slice()
            .iter()
            .zip(gen.as_slice())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn generic_maxmin_solves_widest_path() {
        use crate::apsp::semiring::MaxMin;
        // bottleneck domain: diag = ONE (inf), absent = ZERO (0), capacities > 0
        let mut m = DistMatrix::unconnected(3); // diag 0, off-diag inf — wrong domain
        let n = m.n();
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, if i == j { INF } else { 0.0 });
            }
        }
        m.set(0, 1, 2.0); // thin direct pipe
        m.set(0, 2, 8.0);
        m.set(2, 1, 5.0); // fat detour: bottleneck 5
        let d = solve_semiring::<MaxMin>(&m);
        assert_eq!(d.get(0, 1), 5.0);
        assert_eq!(d.get(0, 2), 8.0);
        assert_eq!(d.get(1, 0), 0.0); // unreachable stays ZERO
    }

    #[test]
    fn matches_slow_reference() {
        // compare against the unhoisted, obviously-literal triple loop
        let g = generators::erdos_renyi(48, 0.3, 11);
        let fast = solve(&g);
        let mut slow = g.clone();
        let n = slow.n();
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    let cand = slow.get(i, k) + slow.get(k, j);
                    if cand < slow.get(i, j) {
                        slow.set(i, j, cand);
                    }
                }
            }
        }
        assert_eq!(fast, slow);
    }
}

//! # fw-stage
//!
//! A production-grade reproduction of **"A Multi-Stage CUDA Kernel for
//! Floyd-Warshall"** (Lund & Smith, 2010) as a three-layer Rust + JAX +
//! Pallas stack:
//!
//! * **Layer 1** (build-time Python): Pallas kernels for the three phases of
//!   blocked Floyd-Warshall, including the paper's staged phase-3 kernel
//!   (`python/compile/kernels/`).
//! * **Layer 2** (build-time Python): the blocked-FW computation graph,
//!   AOT-lowered to HLO text artifacts (`python/compile/model.py`).
//! * **Layer 3** (this crate): the serving coordinator — request routing,
//!   size-bucketed batching, executor pooling over PJRT, result caching,
//!   and the super-blocked tier (`superblock`) that serves arbitrary-n
//!   graphs by running the paper's three-phase schedule over the device
//!   buckets — plus every substrate the reproduction needs: graph generation and I/O,
//!   CPU reference solvers generic over a closed semiring (`apsp::semiring`:
//!   shortest / bottleneck / minimax / reachability objectives, selected per
//!   request), the paper's doubly-tiled data layout (§4.3), and
//!   an analytical Tesla C1060 performance model that regenerates the
//!   paper's Table 1 / Figure 7 (DESIGN.md §Substitutions).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! kernels once, and the `fw-stage` binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! ```no_run
//! use fw_stage::graph::generators;
//! use fw_stage::apsp;
//!
//! let g = generators::erdos_renyi(256, 0.3, 42);
//! let dist = apsp::blocked::solve(&g, 32);
//! assert!(dist.get(0, 0) == 0.0);
//! ```
//!
//! For the full system (PJRT execution of the AOT artifacts, the serving
//! coordinator, the C1060 simulator) see the `runtime`, `coordinator` and
//! `simulator` modules and the `rust/examples/` directory.

pub mod apsp;
pub mod cli;
pub mod coordinator;
pub mod graph;
pub mod layout;
pub mod obs;
pub mod perf;
pub mod runtime;
pub mod simulator;
pub mod superblock;
pub mod util;
pub mod workload;

/// Distance value used across the stack: `f32` with `+inf` for "no path",
/// matching the artifact convention (`python/compile/model.py`).
pub type Dist = f32;

/// Edge weight of missing edges.
pub const INF: Dist = f32::INFINITY;

/// Default tile size `s` (the paper uses 32×32 tiles throughout).
pub const DEFAULT_TILE: usize = 32;

/// Default k-chunk `m` for the staged phase 3 (paper: t=32 staged over 4
/// iterations ⇒ m=8).
pub const DEFAULT_KCHUNK: usize = 8;

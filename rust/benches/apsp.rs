//! CPU-solver microbenchmarks — the substrate numbers every other bench
//! builds on: the Table 1 "CPU" column at laptop scale for each solver
//! family, plus the §4.3 doubly-tiled layout transform (free on the GPU,
//! priced here because the simulator's bandwidth model assumes it).
//!
//! Run: `cargo bench --bench apsp`

mod common;

use fw_stage::graph::generators;
use fw_stage::layout;
use fw_stage::perf::bench;
use fw_stage::{apsp, perf};

fn main() {
    let n = if common::fast_mode() { 128 } else { 256 };
    let n3 = (n as f64).powi(3);
    let g = generators::erdos_renyi(n, 0.3, 17);
    let cfg = common::config_for(n);

    common::banner(&format!("APSP CPU solvers (n={n})"));
    let r = bench("naive triple loop", &cfg, || {
        perf::black_box(apsp::naive::solve(&g));
    });
    println!("{}", r.report_throughput(n3, "tasks"));
    let r = bench("blocked s=32", &cfg, || {
        perf::black_box(apsp::blocked::solve(&g, 32));
    });
    println!("{}", r.report_throughput(n3, "tasks"));
    let r = bench("parallel s=32 t=4", &cfg, || {
        perf::black_box(apsp::parallel::solve(&g, 32, 4));
    });
    println!("{}", r.report_throughput(n3, "tasks"));
    let r = bench("johnson (sparse family)", &cfg, || {
        perf::black_box(apsp::johnson::solve(&g).expect("no negative cycle"));
    });
    println!("{}", r.report_throughput(n3, "tasks"));
    let r = bench("paths (successor matrix)", &cfg, || {
        perf::black_box(apsp::paths::solve(&g));
    });
    println!("{}", r.report_throughput(n3, "tasks"));

    common::banner("doubly-tiled layout transform (§4.3)");
    let data: Vec<f32> = g.as_slice().to_vec();
    let r = bench("to_doubly_tiled s=32 t=4", &cfg, || {
        perf::black_box(layout::to_doubly_tiled(&data, n, 32, 4));
    });
    println!("{}", r.report());
    let tiled = layout::to_doubly_tiled(&data, n, 32, 4);
    let r = bench("from_doubly_tiled s=32 t=4", &cfg, || {
        perf::black_box(layout::from_doubly_tiled(&tiled, n, 32, 4));
    });
    println!("{}", r.report());
}

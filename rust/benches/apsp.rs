//! CPU-solver microbenchmarks — the substrate numbers every other bench
//! builds on: the Table 1 "CPU" column at laptop scale for each solver
//! family, the register-tiled phase-3 microkernel in isolation (packed vs
//! strided column panel), plus the §4.3 doubly-tiled layout transform
//! (free on the GPU, priced here because the simulator's bandwidth model
//! assumes it).
//!
//! Run: `cargo bench --bench apsp`
//!
//! Every run also appends a machine-readable entry to the repo's perf
//! trajectory (`BENCH_apsp.json` at the repo root; `FW_BENCH_JSON=<path>`
//! redirects, `FW_BENCH_JSON=off` disables) — the file CI uploads and the
//! README's perf table quotes.

mod common;

use fw_stage::apsp::kernel::{self, PanelBuf};
use fw_stage::apsp::semiring::{self, MinPlus, Objective};
use fw_stage::apsp::simd;
use fw_stage::graph::generators;
use fw_stage::layout;
use fw_stage::perf::{bench, BenchResult, BenchSink};
use fw_stage::util::json::Json;
use fw_stage::{apsp, perf};

/// Print the human line and record the machine one.
fn emit(sink: &mut BenchSink, r: &BenchResult, units: Option<f64>) {
    match units {
        Some(u) => {
            println!("{}", r.report_throughput(u, "tasks"));
            sink.record_with(r, vec![("tasks_per_sec", Json::Num(u / r.median_s))]);
        }
        None => {
            println!("{}", r.report());
            sink.record(r);
        }
    }
}

fn main() {
    let n = if common::fast_mode() { 128 } else { 256 };
    let n3 = (n as f64).powi(3);
    let g = generators::erdos_renyi(n, 0.3, 17);
    let cfg = common::config_for(n);
    let mut sink = BenchSink::from_env("apsp");
    sink.set_meta("n", Json::Num(n as f64));
    sink.set_meta("fast", Json::Bool(common::fast_mode()));
    // which SIMD ISA the ambient rows below ran on — the trajectory is
    // meaningless without it once runners differ
    sink.set_meta("kernel", Json::str(simd::active().name()));

    common::banner(&format!("APSP CPU solvers (n={n})"));
    let r = bench("naive triple loop", &cfg, || {
        perf::black_box(apsp::naive::solve(&g));
    });
    emit(&mut sink, &r, Some(n3));
    let r = bench("blocked s=32", &cfg, || {
        perf::black_box(apsp::blocked::solve(&g, 32));
    });
    emit(&mut sink, &r, Some(n3));
    let r = bench("parallel s=32 t=4", &cfg, || {
        perf::black_box(apsp::parallel::solve(&g, 32, 4));
    });
    emit(&mut sink, &r, Some(n3));
    let r = bench("johnson (sparse family)", &cfg, || {
        perf::black_box(apsp::johnson::solve(&g).expect("no negative cycle"));
    });
    emit(&mut sink, &r, Some(n3));
    let r = bench("paths (successor matrix)", &cfg, || {
        perf::black_box(apsp::paths::solve(&g));
    });
    emit(&mut sink, &r, Some(n3));

    common::banner("min-plus microkernel (one phase-3 tile, s=32)");
    // one doubly-dependent tile update against panels living in the full
    // n-stride matrix — the unit of work phase 3 performs (nb-1)² times
    // per stage; `tasks` here is the tile's s³ min-plus updates
    let s = 32;
    let s3 = (s as f64).powi(3);
    let data = g.as_slice();
    let mut dst = vec![0f32; s * n];
    dst.copy_from_slice(&data[s * n..2 * s * n]); // tile rows s..2s
    let col = &data[s * n..]; // col panel at (s, 0), stride n
    let row = &data[..s * n]; // row panel rows 0..s, stride n
    let r = bench("phase3 tile strided col", &cfg, || {
        kernel::minplus_panel(&mut dst[s..], n, col, n, &row[s..], n, s, s, s);
        perf::black_box(&dst);
    });
    emit(&mut sink, &r, Some(s3));
    let mut pack = PanelBuf::default();
    let r = bench("phase3 tile packed col", &cfg, || {
        pack.pack_dist(col, n, s, s);
        kernel::minplus_panel(&mut dst[s..], n, pack.dist(), s, &row[s..], n, s, s, s);
        perf::black_box(&dst);
    });
    emit(&mut sink, &r, Some(s3));
    // the generic kernel monomorphized at (min,+) — the semiring refactor's
    // zero-cost claim, priced next to the specialized entry it replaced
    let r = bench("phase3 tile generic<MinPlus>", &cfg, || {
        kernel::panel::<MinPlus>(&mut dst[s..], n, col, n, &row[s..], n, s, s, s);
        perf::black_box(&dst);
    });
    emit(&mut sink, &r, Some(s3));
    // one row per ISA this host can execute (scalar always included) — the
    // scalar-vs-SIMD spread IS the perf trajectory of the vector kernels,
    // and the bitwise conformance gate makes the comparison apples-to-apples
    for isa in simd::available_isas() {
        let r = bench(&format!("phase3 tile s=32 kernel={}", isa.name()), &cfg, || {
            kernel::panel_with::<MinPlus>(isa, &mut dst[s..], n, col, n, &row[s..], n, s, s, s);
            perf::black_box(&dst);
        });
        emit(&mut sink, &r, Some(s3));
    }

    common::banner("semiring objectives, blocked s=32");
    // one row per non-(min,+) serving objective: the same blocked schedule
    // driving a different (⊕, ⊗) pair over the objective-prepared graph
    for obj in [Objective::Bottleneck, Objective::Minimax, Objective::Reachability] {
        let prepared = obj.prepare(&g).expect("generator weights valid for every objective");
        let r = bench(&format!("blocked s=32 {}", obj.name()), &cfg, || {
            perf::black_box(semiring::blocked_solve(obj, &prepared, 32));
        });
        emit(&mut sink, &r, Some(n3));
    }

    common::banner("incremental update vs full recompute (dynamic-graph tier)");
    // the workload the dynamic tier exists for: a small edge-delta batch
    // against an already-solved closure.  `tasks` stays n³ for every row
    // so the tasks/s figures are directly comparable — the incremental
    // rows deliver the same logical result (the closure of the mutated
    // graph) for a fraction of the work.
    use fw_stage::apsp::incremental::{self, EdgeUpdate, UpdateConfig};
    let base = apsp::parallel::solve_paths(&g, 32, 4);
    let ucfg = UpdateConfig { tile: 32, threads: 4, ..UpdateConfig::default() };
    // four decreases on edges the base graph actually has (deterministic)
    let mut dec_batch = Vec::new();
    'dec: for i in 0..n {
        for j in 0..n {
            if i != j && g.get(i, j).is_finite() {
                dec_batch.push(EdgeUpdate { src: i, dst: j, weight: g.get(i, j) * 0.5 });
                if dec_batch.len() == 4 {
                    break 'dec;
                }
            }
        }
    }
    let g_dec = incremental::mutated(&g, &dec_batch).expect("valid batch");
    let r = bench("update 4-edge decrease batch", &cfg, || {
        perf::black_box(
            incremental::update_paths(&g, &base, &dec_batch, &ucfg).expect("update"),
        );
    });
    emit(&mut sink, &r, Some(n3));
    let r = bench("recompute after 4-edge decrease", &cfg, || {
        perf::black_box(apsp::parallel::solve_paths(&g_dec, 32, 4));
    });
    emit(&mut sink, &r, Some(n3));
    // one deletion: the increase path (successor-forest damage detection +
    // row-bounded re-solve, or a threshold recompute when damage is wide)
    let del = dec_batch[0];
    let inc_batch = vec![EdgeUpdate { src: del.src, dst: del.dst, weight: f32::INFINITY }];
    let g_inc = incremental::mutated(&g, &inc_batch).expect("valid batch");
    let r = bench("update 1-edge deletion", &cfg, || {
        perf::black_box(
            incremental::update_paths(&g, &base, &inc_batch, &ucfg).expect("update"),
        );
    });
    emit(&mut sink, &r, Some(n3));
    let r = bench("recompute after 1-edge deletion", &cfg, || {
        perf::black_box(apsp::parallel::solve_paths(&g_inc, 32, 4));
    });
    emit(&mut sink, &r, Some(n3));

    common::banner("doubly-tiled layout transform (§4.3)");
    let data: Vec<f32> = g.as_slice().to_vec();
    let r = bench("to_doubly_tiled s=32 t=4", &cfg, || {
        perf::black_box(layout::to_doubly_tiled(&data, n, 32, 4));
    });
    emit(&mut sink, &r, None);
    let tiled = layout::to_doubly_tiled(&data, n, 32, 4);
    let r = bench("from_doubly_tiled s=32 t=4", &cfg, || {
        perf::black_box(layout::from_doubly_tiled(&tiled, n, 32, 4));
    });
    emit(&mut sink, &r, None);

    match sink.finish() {
        Ok(Some(path)) => println!("\nperf trajectory appended: {}", path.display()),
        Ok(None) => println!("\nperf trajectory sink disabled (FW_BENCH_JSON=off)"),
        Err(e) => eprintln!("\nWARN: could not write perf trajectory: {e}"),
    }
}

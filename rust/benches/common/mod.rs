//! Shared helpers for the bench binaries (`cargo bench` targets with
//! `harness = false`, driven by `fw_stage::perf`).

use std::path::PathBuf;
use std::time::Duration;

use fw_stage::perf::BenchConfig;
use fw_stage::runtime::ExecutorPool;

/// Artifact directory if built (benches degrade to simulator/CPU-only
/// sections when missing).
#[allow(dead_code)]
pub fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[allow(dead_code)]
pub fn open_pool() -> Option<ExecutorPool> {
    let dir = artifact_dir()?;
    match ExecutorPool::open(&dir) {
        Ok(pool) => Some(pool),
        Err(e) => {
            eprintln!("WARN: artifacts present but pool failed to open: {e:#}");
            None
        }
    }
}

/// Config scaled to the expected per-iteration cost so total bench time
/// stays bounded (device solves at n=512 run ~2 s each).
#[allow(dead_code)]
pub fn config_for(n: usize) -> BenchConfig {
    if n >= 512 {
        BenchConfig {
            measure_time: Duration::from_secs(6),
            warmup_time: Duration::from_millis(10),
            max_samples: 3,
            min_samples: 2,
        }
    } else if n >= 256 {
        BenchConfig {
            measure_time: Duration::from_secs(3),
            warmup_time: Duration::from_millis(50),
            max_samples: 8,
            min_samples: 3,
        }
    } else {
        BenchConfig {
            measure_time: Duration::from_secs(1),
            warmup_time: Duration::from_millis(100),
            max_samples: 30,
            min_samples: 5,
        }
    }
}

/// `FW_BENCH_FAST=1` trims sweeps for CI-style smoke runs.
#[allow(dead_code)]
pub fn fast_mode() -> bool {
    std::env::var("FW_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

#[allow(dead_code)]
pub fn banner(title: &str) {
    println!();
    println!("{}", "=".repeat(78));
    println!("{title}");
    println!("{}", "=".repeat(78));
}

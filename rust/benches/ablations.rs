//! E5/E8 — ablations over the design choices DESIGN.md calls out.
//!
//! 1. **§4 speedup decomposition** (simulated): instruction optimization
//!    and staging toggled independently, plus the cyclic-k bank-conflict
//!    fix — the factors whose product is the paper's ≈5.2×.
//! 2. **k-chunk sweep** (measured): staged artifacts lowered with
//!    m ∈ {4, 8, 16, 32} at n=256 — the paper stages t=32 over 4
//!    iterations (m=8); this measures that choice on the XLA substrate.
//! 3. **CPU tile sweep**: blocked FW with s ∈ {8…128} — the cache-blocking
//!    curve (Venkataraman et al. [4]) that motivated blocking in the first
//!    place.
//! 4. **Thread scaling**: the parallel phase-3 fan-out.
//!
//! Run: `cargo bench --bench ablations`

mod common;

use fw_stage::graph::generators;
use fw_stage::perf::bench;
use fw_stage::runtime::Manifest;
use fw_stage::simulator::table::render_ablation;
use fw_stage::{apsp, perf};

fn main() {
    common::banner("E5 — §4 speedup decomposition (simulated C1060)");
    print!("{}", render_ablation(16384));

    common::banner("E8 — staged k-chunk sweep (measured, n=256 artifacts)");
    match common::artifact_dir().map(|d| (Manifest::load(&d), d)) {
        Some((Ok(manifest), dir)) => {
            let pool = fw_stage::runtime::ExecutorPool::open(&dir).expect("pool");
            let g = generators::erdos_renyi(256, 0.3, 7);
            let cfg = common::config_for(256);
            // kchunk ablation artifacts carry the _m tag in their names
            let mut entries: Vec<_> = manifest
                .entries
                .iter()
                .filter(|e| e.variant == "staged" && e.n == 256)
                .collect();
            entries.sort_by_key(|e| e.kchunk);
            for entry in entries {
                let model = pool.model_for_entry(entry).expect("compile");
                let padded = g.padded(entry.n);
                model.run(&padded).expect("warm");
                let r = bench(&entry.name, &cfg, || {
                    perf::black_box(model.run(&padded).expect("run"));
                });
                println!(
                    "m={:<3} ({:<32}) median {}",
                    entry.kchunk.unwrap_or(0),
                    entry.name,
                    perf::format_time(r.median_s)
                );
            }
        }
        _ => println!("(artifacts not built — skipped)"),
    }

    common::banner("E8 — CPU blocked-FW tile sweep (cache blocking)");
    let n = if common::fast_mode() { 256 } else { 512 };
    let g = generators::erdos_renyi(n, 0.3, 13);
    let cfg = common::config_for(n);
    let naive = bench("naive", &cfg, || {
        perf::black_box(apsp::naive::solve(&g));
    });
    println!(
        "n={n}: naive {}  (baseline)",
        perf::format_time(naive.median_s)
    );
    for s in [8usize, 16, 32, 64, 128] {
        let r = bench("blocked", &cfg, || {
            perf::black_box(apsp::blocked::solve(&g, s));
        });
        println!(
            "s={s:<4} median {}  ({:.2}× vs naive)",
            perf::format_time(r.median_s),
            naive.median_s / r.median_s
        );
    }

    common::banner("E8 — parallel phase-3 thread scaling");
    for threads in [1usize, 2, 4, 8] {
        let r = bench("parallel", &cfg, || {
            perf::black_box(apsp::parallel::solve(&g, 32, threads));
        });
        println!(
            "threads={threads:<3} median {}  ({:.2}× vs naive)",
            perf::format_time(r.median_s),
            naive.median_s / r.median_s
        );
    }
}

//! E3/E4/E7 — the paper's §5 analysis: tasks/second, bandwidth accounting,
//! and the CPU time constant.
//!
//! * simulated §5 block (H&N 2.6e9, K&K 14.9e9, staged 73.6e9 tasks/s and
//!   the FLOPs-per-task derivations) — absolute reproduction;
//! * measured tasks/s for every implementation on this machine, with the
//!   bytes-per-task accounting of §3.1 applied to the measured rates;
//! * E7: the measured n³ time constant of the CPU baseline (the paper's
//!   footnote-1 arithmetic re-done on this host).
//!
//! Run: `cargo bench --bench tasks_per_sec`

mod common;

use fw_stage::graph::generators;
use fw_stage::perf::bench;
use fw_stage::simulator::table::render_analysis;
use fw_stage::{apsp, perf};

fn main() {
    common::banner("§5 analysis — simulated C1060 (absolute reproduction)");
    print!("{}", render_analysis());

    common::banner("§5 analysis — measured on this machine");
    let n = if common::fast_mode() { 128 } else { 256 };
    let n3 = (n as f64).powi(3);
    let g = generators::erdos_renyi(n, 0.3, 99);
    let cfg = common::config_for(n);

    println!("problem size n={n} ({n3:.3e} tasks per solve)\n");
    println!(
        "{:<28} {:>12} {:>16} {:>16}",
        "implementation", "median", "tasks/s", "implied GB/s @16B"
    );
    let report = |name: &str, median_s: f64| {
        println!(
            "{:<28} {:>12} {:>16.3e} {:>16.2}",
            name,
            perf::format_time(median_s),
            n3 / median_s,
            n3 * 16.0 / median_s / 1e9,
        );
    };

    let r = bench("cpu-naive", &cfg, || {
        perf::black_box(apsp::naive::solve(&g));
    });
    report("cpu naive (Table1 col 1)", r.median_s);
    let r = bench("cpu-blocked", &cfg, || {
        perf::black_box(apsp::blocked::solve(&g, 32));
    });
    report("cpu blocked s=32", r.median_s);
    let r = bench("cpu-parallel", &cfg, || {
        perf::black_box(apsp::parallel::solve(&g, 32, 4));
    });
    report("cpu blocked s=32 ×4 threads", r.median_s);
    let r = bench("cpu-johnson", &cfg, || {
        perf::black_box(apsp::johnson::solve(&g).expect("no neg cycle"));
    });
    report("cpu Johnson (sparse family)", r.median_s);

    if let Some(pool) = common::open_pool() {
        for variant in ["naive", "blocked", "staged"] {
            pool.solve(variant, &g).expect("warm");
            let r = bench(variant, &cfg, || {
                perf::black_box(pool.solve(variant, &g).expect("solve"));
            });
            report(&format!("device {variant} (PJRT/XLA-CPU)"), r.median_s);
        }
    } else {
        println!("(artifacts not built — device rows skipped)");
    }

    common::banner("E7 — CPU time constant (paper footnote 1 arithmetic)");
    let mut constants = Vec::new();
    for n in [128usize, 192, 256] {
        let g = generators::erdos_renyi(n, 0.3, n as u64);
        let cfg = common::config_for(n);
        let r = bench("cpu-const", &cfg, || {
            perf::black_box(apsp::naive::solve(&g));
        });
        let c = r.median_s / (n as f64).powi(3);
        constants.push(c);
        println!("n={n:<5} median {}  → {c:.3e} s/task", perf::format_time(r.median_s));
    }
    let mean_c = constants.iter().sum::<f64>() / constants.len() as f64;
    println!(
        "\nthis host: ≈{mean_c:.2e} s/task  (paper's 2009 Phenom: 2.2e-9; staged C1060: 1.2e-11)"
    );
    println!(
        "projected n=16384 CPU time on this host: {:.0}s (paper CPU: extrapolated ~9500s)",
        mean_c * 16384f64.powi(3)
    );
}

//! E2 — regenerate the paper's **Figure 7** (log-scale runtime curves).
//!
//! Emits CSV series ready for plotting:
//! * `fig7_simulated.csv` — all five implementations at the 17 paper sizes
//!   on the simulated C1060 (the absolute reproduction);
//! * `fig7_measured.csv` — the measured laptop-scale series on this
//!   machine (CPU + device variants).
//!
//! Files land in `target/bench-results/`; both are also printed.
//!
//! Run: `cargo bench --bench fig7`

mod common;

use std::fs;
use std::path::PathBuf;

use fw_stage::graph::generators;
use fw_stage::perf::bench;
use fw_stage::simulator::table::fig7_csv;
use fw_stage::{apsp, perf};

fn out_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/bench-results");
    fs::create_dir_all(&dir).expect("creating bench-results dir");
    dir
}

fn main() {
    common::banner("Figure 7 / simulated series (C1060 model, 17 paper sizes)");
    let sim = fig7_csv();
    print!("{sim}");
    let sim_path = out_dir().join("fig7_simulated.csv");
    fs::write(&sim_path, &sim).unwrap();
    println!("→ wrote {}", sim_path.display());

    common::banner("Figure 7 / measured series (this machine)");
    let sizes: &[usize] = if common::fast_mode() {
        &[64, 128]
    } else {
        &[64, 128, 256, 512]
    };
    let pool = common::open_pool();
    let mut csv = String::from("n,cpu_naive,cpu_blocked,cpu_parallel4,dev_naive,dev_blocked,dev_staged\n");
    for &n in sizes {
        let g = generators::erdos_renyi(n, 0.3, n as u64);
        let cfg = common::config_for(n);
        let mut cells = vec![n.to_string()];
        let r = bench("cpu_naive", &cfg, || {
            perf::black_box(apsp::naive::solve(&g));
        });
        cells.push(format!("{:.6}", r.median_s));
        let r = bench("cpu_blocked", &cfg, || {
            perf::black_box(apsp::blocked::solve(&g, 32));
        });
        cells.push(format!("{:.6}", r.median_s));
        let r = bench("cpu_parallel4", &cfg, || {
            perf::black_box(apsp::parallel::solve(&g, 32, 4));
        });
        cells.push(format!("{:.6}", r.median_s));
        match &pool {
            Some(pool) => {
                for variant in ["naive", "blocked", "staged"] {
                    pool.solve(variant, &g).expect("warm");
                    let r = bench(variant, &cfg, || {
                        perf::black_box(pool.solve(variant, &g).expect("solve"));
                    });
                    cells.push(format!("{:.6}", r.median_s));
                }
            }
            None => cells.extend(["".into(), "".into(), "".into()]),
        }
        let line = cells.join(",");
        println!("{line}");
        csv.push_str(&line);
        csv.push('\n');
    }
    let measured_path = out_dir().join("fig7_measured.csv");
    fs::write(&measured_path, &csv).unwrap();
    println!("→ wrote {}", measured_path.display());
}

//! E1 — regenerate the paper's **Table 1** (implementation comparison).
//!
//! Section A: the analytical C1060 simulation at all 17 paper sizes, next
//! to the paper's reported numbers (absolute reproduction; hardware
//! substituted per DESIGN.md).
//!
//! Section B: *measured* wall-clock on this machine at laptop scale
//! (n = 64…512) for every implementation that actually runs here: the CPU
//! baselines and the three device variants through PJRT.  This is the
//! Table 1 *shape* check on real executions: blocked beats naive on the
//! device, staged ≈ blocked under interpret-mode lowering (the scheduling
//! effect the paper measures needs real hardware; see DESIGN.md
//! §Hardware-Adaptation).
//!
//! Run: `cargo bench --bench table1`

mod common;

use fw_stage::graph::generators;
use fw_stage::perf::bench;
use fw_stage::simulator::table::render_table1;
use fw_stage::{apsp, perf};

fn main() {
    common::banner("Table 1 / Section A — simulated NVIDIA Tesla C1060 (paper testbed)");
    print!("{}", render_table1());

    common::banner("Table 1 / Section B — measured on this machine");
    let sizes: &[usize] = if common::fast_mode() {
        &[64, 128]
    } else {
        &[64, 128, 256, 512]
    };
    let pool = common::open_pool();
    if pool.is_none() {
        println!("(artifacts not built — device rows skipped; run `make artifacts`)");
    }

    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14} {:>14} {:>14}",
        "n", "cpu-naive", "cpu-blocked", "cpu-par(4)", "dev-naive", "dev-blocked", "dev-staged"
    );
    for &n in sizes {
        let g = generators::erdos_renyi(n, 0.3, n as u64);
        let cfg = common::config_for(n);
        let mut row = vec![format!("{n:>6}")];

        let r = bench("cpu-naive", &cfg, || {
            perf::black_box(apsp::naive::solve(&g));
        });
        row.push(format!("{:>14}", perf::format_time(r.median_s)));
        let r = bench("cpu-blocked", &cfg, || {
            perf::black_box(apsp::blocked::solve(&g, 32));
        });
        row.push(format!("{:>14}", perf::format_time(r.median_s)));
        let r = bench("cpu-par", &cfg, || {
            perf::black_box(apsp::parallel::solve(&g, 32, 4));
        });
        row.push(format!("{:>14}", perf::format_time(r.median_s)));

        match &pool {
            Some(pool) => {
                for variant in ["naive", "blocked", "staged"] {
                    // warm compile outside the timed region
                    pool.solve(variant, &g).expect("warm solve");
                    let r = bench(variant, &cfg, || {
                        perf::black_box(pool.solve(variant, &g).expect("solve"));
                    });
                    row.push(format!("{:>14}", perf::format_time(r.median_s)));
                }
            }
            None => {
                for _ in 0..3 {
                    row.push(format!("{:>14}", "—"));
                }
            }
        }
        println!("{}", row.join(" "));
    }
    println!();
    println!("notes: device rows execute the AOT Pallas artifacts on XLA-CPU (interpret-");
    println!("mode lowering); absolute numbers are CPU-substrate times, the cross-variant");
    println!("shape is the reproduction target. Simulated section carries the paper-scale");
    println!("absolute claims.");
}

//! L3 coordinator benchmarks: request-path overhead, cache-hit latency,
//! and block-diagonal batching throughput (the §Perf targets of DESIGN.md).
//!
//! Run: `cargo bench --bench coordinator`

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use fw_stage::apsp::incremental::{self, EdgeUpdate};
use fw_stage::coordinator::cache::graph_fingerprint;
use fw_stage::coordinator::{
    client::Client, server::Server, Config, Coordinator, Request, UpdateOutcome, UpdateRequest,
};
use fw_stage::graph::generators;
use fw_stage::perf::{bench, black_box, format_time};
use fw_stage::superblock::{self, SuperBlockConfig};
use fw_stage::util::stats::Samples;
use fw_stage::workload::{self, TraceConfig};

/// Super-block schedule with the CPU diagonal tier: single-thread schedule
/// vs the dependency-streaming pool.  Needs no artifacts — the tile math is
/// identical either way (asserted), only the wall clock moves.
fn sb_cfg(bucket: usize, workers: usize) -> SuperBlockConfig {
    SuperBlockConfig {
        bucket,
        workers,
        profile: false,
    }
}

fn superblock_schedule_section() {
    common::banner("superblock schedule — CPU diagonal tier, pool width sweep");
    let (n, bucket) = if common::fast_mode() { (512, 128) } else { (1024, 256) };
    let g = generators::scale_free(n, 2, 7);
    let t0 = Instant::now();
    let (single, report) = superblock::solve_cpu(&g, &sb_cfg(bucket, 1));
    let one = t0.elapsed().as_secs_f64();
    println!(
        "n={n} bucket={bucket} workers=1    {}   ({} rounds, {} tiles)",
        format_time(one),
        report.round_count(),
        report.total_tiles()
    );
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let t0 = Instant::now();
    let (multi, _) = superblock::solve_cpu(&g, &sb_cfg(bucket, workers));
    let many = t0.elapsed().as_secs_f64();
    assert_eq!(single, multi, "pool width changed the closure");
    println!(
        "n={n} bucket={bucket} workers={workers:<2}   {}   ({:.2}× speedup vs single-thread)",
        format_time(many),
        one / many
    );
}

fn main() {
    superblock_schedule_section();

    let Some(dir) = common::artifact_dir() else {
        println!("(artifacts not built — remaining coordinator benches need `make artifacts`)");
        return;
    };

    // ---- request-path overhead: engine round trip vs direct pool call ----
    common::banner("coordinator overhead — direct pool vs engine round-trip vs TCP");
    let n = 128;
    let g = generators::erdos_renyi(n, 0.3, 5);
    let cfg = common::config_for(n);

    let pool = fw_stage::runtime::ExecutorPool::open(&dir).expect("pool");
    pool.solve("staged", &g).expect("warm");
    let direct = bench("direct pool.solve", &cfg, || {
        black_box(pool.solve("staged", &g).expect("solve"));
    });
    println!("direct pool.solve      {}", format_time(direct.median_s));
    drop(pool);

    let mut config = Config::new(&dir);
    config.cache_capacity = 64;
    config.engine.batch_window = Duration::from_millis(0);
    let coord = Arc::new(Coordinator::start(config).expect("coordinator"));
    coord.solve_graph(&g, "staged").expect("warm");
    let engine = bench("coordinator.solve", &cfg, || {
        black_box(
            coord
                .solve(&Request {
                    id: 0,
                    graph: g.clone(),
                    variant: "staged".into(),
                    no_cache: true,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("solve"),
        );
    });
    println!(
        "coordinator.solve      {}   (+{:.1}% vs direct)",
        format_time(engine.median_s),
        (engine.median_s / direct.median_s - 1.0) * 100.0
    );

    let server = Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("client");
    // different seeds to dodge the cache; measure full TCP round trip
    let mut tcp = Samples::new();
    for i in 0..10 {
        let g = generators::erdos_renyi(n, 0.3, 1000 + i);
        let t0 = Instant::now();
        client.solve(&g, "staged").expect("tcp solve");
        tcp.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "TCP client.solve       {}   (+{:.1}% vs direct; includes JSON codec)",
        format_time(tcp.median()),
        (tcp.median() / direct.median_s - 1.0) * 100.0
    );

    // ---- cache hit path ----
    common::banner("cache-hit latency");
    let g_cached = generators::erdos_renyi(n, 0.3, 42);
    coord.solve_graph(&g_cached, "staged").expect("prime cache");
    let hit = bench("cache hit", &common::config_for(64), || {
        black_box(
            coord
                .solve(&Request {
                    id: 0,
                    graph: g_cached.clone(),
                    variant: "staged".into(),
                    no_cache: false,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("hit"),
        );
    });
    println!(
        "cache hit              {}   ({:.0}× faster than device solve)",
        format_time(hit.median_s),
        engine.median_s / hit.median_s
    );

    // ---- incremental update path vs full recompute through the stack ----
    // the dynamic-graph tier: a cached (dist, succ) closure is the base
    // state; update requests ship only edge deltas against its fingerprint
    common::banner("incremental update vs recompute — coordinator request path");
    let g_upd = generators::erdos_renyi(n, 0.3, 77);
    coord
        .solve(&Request {
            id: 0,
            graph: g_upd.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: true, // successor-carrying base: increases stay incremental
            objective: "shortest".into(),
            trace: false,
        })
        .expect("prime update base");
    let mut delta = Vec::new();
    'delta: for i in 0..n {
        for j in 0..n {
            if i != j && g_upd.get(i, j).is_finite() {
                delta.push(EdgeUpdate { src: i, dst: j, weight: g_upd.get(i, j) * 0.5 });
                if delta.len() == 4 {
                    break 'delta;
                }
            }
        }
    }
    let fp = graph_fingerprint(&g_upd);
    let upd = bench("coordinator.update (4-edge delta)", &common::config_for(64), || {
        let outcome = coord
            .update(&UpdateRequest {
                id: 0,
                variant: "staged".into(),
                n: g_upd.n(),
                base_fingerprint: fp,
                updates: delta.clone(),
                want_paths: false,
                objective: "shortest".into(),
            })
            .expect("update");
        match outcome {
            UpdateOutcome::Solved(resp) => black_box(resp),
            UpdateOutcome::BaseMissing { .. } => panic!("base evicted mid-bench"),
        };
    });
    let g_upd_mut = incremental::mutated(&g_upd, &delta).expect("valid batch");
    let recompute = bench("full solve of mutated graph", &cfg, || {
        black_box(
            coord
                .solve(&Request {
                    id: 0,
                    graph: g_upd_mut.clone(),
                    variant: "staged".into(),
                    no_cache: true,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("solve"),
        );
    });
    println!(
        "update (incremental)   {}",
        format_time(upd.median_s)
    );
    println!(
        "recompute (no cache)   {}   (incremental is {:.1}× faster)",
        format_time(recompute.median_s),
        recompute.median_s / upd.median_s
    );

    // short update-heavy trace replay: deltas chain across fingerprints
    let trace = workload::generate(&TraceConfig {
        count: 16,
        ..TraceConfig::update_heavy(0xD17A)
    });
    let mut current: std::collections::HashMap<(usize, u64), fw_stage::graph::DistMatrix> =
        std::collections::HashMap::new();
    let t0 = Instant::now();
    let mut applied = 0u64;
    for item in &trace {
        let key = (item.n, item.seed);
        let base = current.entry(key).or_insert_with(|| item.graph());
        if item.updates.is_empty() {
            coord
                .solve(&Request {
                    id: 0,
                    graph: base.clone(),
                    variant: "staged".into(),
                    no_cache: false,
                    want_paths: true,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("trace solve");
            continue;
        }
        let outcome = coord
            .update(&UpdateRequest {
                id: 0,
                variant: "staged".into(),
                n: base.n(),
                base_fingerprint: graph_fingerprint(base),
                updates: item.updates.clone(),
                want_paths: false,
                objective: "shortest".into(),
            })
            .expect("trace update");
        if matches!(outcome, UpdateOutcome::Solved(_)) {
            applied += 1;
        }
        *base = incremental::mutated(base, &item.updates).expect("valid trace batch");
    }
    let trace_s = t0.elapsed().as_secs_f64();
    let snap = coord.metrics().snapshot();
    println!(
        "update-heavy trace     {}   ({applied} chained updates; {} edges, {} recomputes)",
        format_time(trace_s),
        snap.get("update_edges"),
        snap.get("update_recomputes"),
    );

    // ---- batching throughput: packable small graphs vs sequential ----
    // n=30 graphs share the 64 bucket two-at-a-time: the cost-model packer
    // halves the number of device calls (see batcher.rs for why packing
    // never escalates to a larger bucket)
    common::banner("block-diagonal batching — 8 × n=30 concurrent requests");
    let graphs: Vec<_> = (0..8u64)
        .map(|i| generators::erdos_renyi(30, 0.35, 2000 + i))
        .collect();

    // one coordinator for both modes: device route forced (cpu_threshold 0)
    let mut config = Config::new(&dir);
    config.engine.batch_window = Duration::from_millis(5);
    config.router.cpu_threshold = 0; // small graphs must reach the engine
    config.cache_capacity = 0;
    let batching = Arc::new(Coordinator::start(config).expect("coordinator"));
    batching
        .solve_graph(&graphs[0], "staged")
        .expect("warm batching coordinator");

    // sequential: one at a time ⇒ every engine round holds a single job
    let t0 = Instant::now();
    for g in &graphs {
        batching
            .solve(&Request {
                id: 0,
                graph: g.clone(),
                variant: "staged".into(),
                no_cache: true,
                want_paths: false,
                objective: "shortest".into(),
                trace: false,
            })
            .expect("sequential");
    }
    let sequential = t0.elapsed().as_secs_f64();
    let bserver = Server::spawn(batching.clone(), "127.0.0.1:0").expect("server");
    let baddr = bserver.addr().to_string();
    let t0 = Instant::now();
    let handles: Vec<_> = graphs
        .iter()
        .cloned()
        .map(|g| {
            let addr = baddr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("client");
                c.solve(&g, "staged").expect("solve")
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let concurrent = t0.elapsed().as_secs_f64();
    let snap = batching.metrics().snapshot();
    println!("sequential (8 calls)   {}", format_time(sequential));
    println!(
        "batched    (packed)    {}   ({:.2}× speedup)",
        format_time(concurrent),
        sequential / concurrent
    );
    println!(
        "engine batches: {} device calls for {} items",
        snap.get("batches"),
        snap.get("batched_items")
    );

    // ---- super-block tier through the coordinator (device diagonal) ----
    // larger than every artifact bucket: the router sends it to the
    // superblock tier, whose diagonal tiles loop back through the engine
    common::banner("superblock tier — oversize request through the coordinator");
    let n_sb = if common::fast_mode() { 600 } else { 1024 };
    let g_sb = generators::scale_free(n_sb, 2, 77);
    let t0 = Instant::now();
    let resp = batching
        .solve(&Request {
            id: 0,
            graph: g_sb.clone(),
            variant: "staged".into(),
            no_cache: true,
            want_paths: false,
            objective: "shortest".into(),
            trace: false,
        })
        .expect("superblock solve");
    let sb_seconds = t0.elapsed().as_secs_f64();
    println!(
        "coordinator n={n_sb}    {}   (source {}, super-bucket {})",
        format_time(sb_seconds),
        resp.source.name(),
        resp.bucket
    );
    let snap = batching.metrics().snapshot();
    println!(
        "superblock rounds: {}  tile updates: {}",
        snap.get("superblock_rounds"),
        snap.get("superblock_tiles")
    );
}

//! L3 coordinator benchmarks: request-path overhead, cache-hit latency,
//! block-diagonal batching throughput, the binary matrix frame codec, and
//! front-end saturation (worker pool + bounded queue) — the §Perf targets
//! of DESIGN.md.
//!
//! Run: `cargo bench --bench coordinator`
//!
//! `FW_SATURATION_ONLY=1` runs just the artifact-free frame + saturation
//! sections (the CI smoke step).  `FW_SATURATION_CHECK=1` turns the
//! saturation section's expectations into assertions: a 10×-capacity load
//! must shed, every reply must be a result or a typed error, and the
//! binary frame must decode ≥ 5× faster than line-JSON.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fw_stage::apsp::incremental::{self, EdgeUpdate};
use fw_stage::apsp::paths::NO_PATH;
use fw_stage::coordinator::cache::graph_fingerprint;
use fw_stage::coordinator::types::{
    decode_response, encode_request_opts, encode_response, WireOptions,
};
use fw_stage::coordinator::{
    self, client::Client, frame, server::Server, server::ServerConfig, Config, Coordinator,
    Request, Response, Source, UpdateOutcome, UpdateRequest,
};
use fw_stage::graph::{generators, DistMatrix};
use fw_stage::perf::{bench, black_box, format_time, BenchSink};
use fw_stage::superblock::{self, SuperBlockConfig};
use fw_stage::util::json::Json;
use fw_stage::util::stats::Samples;
use fw_stage::workload::{self, TraceConfig};

/// Super-block schedule with the CPU diagonal tier: single-thread schedule
/// vs the dependency-streaming pool.  Needs no artifacts — the tile math is
/// identical either way (asserted), only the wall clock moves.
fn sb_cfg(bucket: usize, workers: usize) -> SuperBlockConfig {
    SuperBlockConfig {
        bucket,
        workers,
        profile: false,
    }
}

fn superblock_schedule_section() {
    common::banner("superblock schedule — CPU diagonal tier, pool width sweep");
    let (n, bucket) = if common::fast_mode() { (512, 128) } else { (1024, 256) };
    let g = generators::scale_free(n, 2, 7);
    let t0 = Instant::now();
    let (single, report) = superblock::solve_cpu(&g, &sb_cfg(bucket, 1));
    let one = t0.elapsed().as_secs_f64();
    println!(
        "n={n} bucket={bucket} workers=1    {}   ({} rounds, {} tiles)",
        format_time(one),
        report.round_count(),
        report.total_tiles()
    );
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let t0 = Instant::now();
    let (multi, _) = superblock::solve_cpu(&g, &sb_cfg(bucket, workers));
    let many = t0.elapsed().as_secs_f64();
    assert_eq!(single, multi, "pool width changed the closure");
    println!(
        "n={n} bucket={bucket} workers={workers:<2}   {}   ({:.2}× speedup vs single-thread)",
        format_time(many),
        one / many
    );
}

fn check_mode() -> bool {
    std::env::var("FW_SATURATION_CHECK").map(|v| v == "1").unwrap_or(false)
}

static SYNTH_DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Coordinator over a synthetic single-artifact manifest (same trick as
/// the conformance suite): the frame and saturation sections measure the
/// serving surface, not the device tier, so they must run without
/// `make artifacts` — that is what lets CI smoke them before artifacts
/// are built.
fn synthetic_coordinator() -> Coordinator {
    let dir = std::env::temp_dir().join(format!(
        "fw-stage-bench-{}-{}",
        std::process::id(),
        SYNTH_DIR_SEQ.fetch_add(1, Ordering::SeqCst)
    ));
    std::fs::create_dir_all(&dir).expect("create synthetic artifact dir");
    let hlo = "HLO placeholder (never compiled by this bench)\n";
    std::fs::write(dir.join("apsp_staged_n64.hlo.txt"), hlo).expect("write fake artifact");
    let manifest = format!(
        r#"{{"version": 2, "tile": 32, "artifacts": [
            {{"name": "apsp_staged_n64.hlo.txt", "variant": "staged", "n": 64,
              "tile": 32, "dtype": "f32", "input_shape": [64, 64],
              "output_shape": [64, 64], "bytes": {}}}]}}"#,
        hlo.len()
    );
    std::fs::write(dir.join("manifest.json"), manifest).expect("write manifest");
    let mut config = Config::new(&dir);
    config.engine.warm_variants = Vec::new();
    Coordinator::start(config).expect("synthetic coordinator")
}

/// A deterministic dense response (inf + NO_PATH sprinkled in) sized like
/// real serving traffic, for codec measurement without a solve.
fn codec_response(n: usize) -> Response {
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    let mut dist = vec![0f32; n * n];
    let mut succ = vec![0usize; n * n];
    for idx in 0..n * n {
        let r = next();
        dist[idx] = if idx % 97 == 13 {
            f32::INFINITY
        } else {
            (r % 100_000) as f32 / 64.0
        };
        succ[idx] = if idx % 11 == 3 { NO_PATH } else { (r % n as u64) as usize };
    }
    for i in 0..n {
        dist[i * n + i] = 0.0;
    }
    Response {
        id: 42,
        dist: DistMatrix::from_vec(n, dist),
        succ: Some(succ),
        source: Source::Cpu,
        bucket: n,
        seconds: 0.125,
    }
}

fn median_secs(mut run: impl FnMut()) -> f64 {
    let mut s = Samples::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        run();
        s.push(t0.elapsed().as_secs_f64());
    }
    s.median()
}

/// Binary matrix frame vs line-JSON: same response, both codecs, wall
/// clock and wire bytes.  The frame's claim is decode speed — raw
/// little-endian rows memcpy into place, while JSON re-parses every float
/// — so that is the ratio the check mode pins (≥ 5×).
fn frame_codec_section(sink: &mut BenchSink) {
    common::banner("binary matrix frame vs line-JSON codec");
    let n = if common::fast_mode() { 256 } else { 1024 };
    let resp = codec_response(n);

    let json_line = encode_response(&resp);
    let frame_bytes = frame::encode_frame(&resp);
    let json_encode = median_secs(|| {
        black_box(encode_response(&resp));
    });
    let frame_encode = median_secs(|| {
        black_box(frame::encode_frame(&resp));
    });
    let json_decode = median_secs(|| {
        black_box(decode_response(&json_line).expect("json decode"));
    });
    let frame_decode = median_secs(|| {
        black_box(frame::read_frame(&mut &frame_bytes[..]).expect("frame decode"));
    });

    // both codecs must reproduce the matrices bit-for-bit
    let via_json = decode_response(&json_line).expect("json decode");
    let via_frame = frame::read_frame(&mut &frame_bytes[..]).expect("frame decode");
    for (a, b) in [(&via_json, &resp), (&via_frame, &resp)] {
        assert_eq!(a.dist.n(), b.dist.n());
        assert!(
            a.dist
                .as_slice()
                .iter()
                .zip(b.dist.as_slice())
                .all(|(x, y)| x.to_bits() == y.to_bits()),
            "codec round-trip is not bitwise"
        );
        assert_eq!(a.succ, b.succ, "codec round-trip lost successors");
    }

    let size_ratio = json_line.len() as f64 / frame_bytes.len() as f64;
    let decode_ratio = json_decode / frame_decode;
    println!(
        "n={n} line-JSON    encode {}  decode {}  {} bytes",
        format_time(json_encode),
        format_time(json_decode),
        json_line.len()
    );
    println!(
        "n={n} binary frame encode {}  decode {}  {} bytes",
        format_time(frame_encode),
        format_time(frame_decode),
        frame_bytes.len()
    );
    println!(
        "frame is {size_ratio:.2}× smaller on the wire and decodes {decode_ratio:.1}× faster"
    );
    sink.record_json(Json::obj(vec![
        ("bench", Json::str("frame_codec")),
        ("n", Json::num(n as f64)),
        ("json_bytes", Json::num(json_line.len() as f64)),
        ("frame_bytes", Json::num(frame_bytes.len() as f64)),
        ("json_encode_s", Json::Num(json_encode)),
        ("frame_encode_s", Json::Num(frame_encode)),
        ("json_decode_s", Json::Num(json_decode)),
        ("frame_decode_s", Json::Num(frame_decode)),
        ("size_ratio", Json::Num(size_ratio)),
        ("decode_ratio", Json::Num(decode_ratio)),
    ]));
    if check_mode() {
        assert!(
            decode_ratio >= 5.0,
            "binary frame should decode ≥ 5× faster than line-JSON (got {decode_ratio:.1}×)"
        );
        assert!(
            size_ratio > 1.0,
            "binary frame should be smaller than line-JSON (got {size_ratio:.2}×)"
        );
    }
}

/// One closed-loop client: `count` back-to-back solves over its own
/// connection, classifying every reply.
struct ClientTally {
    ok: usize,
    shed: usize,
    deadline: usize,
    other: usize,
    latencies: Vec<f64>,
}

fn saturation_client(addr: &str, n: usize, seed_base: u64, count: usize) -> ClientTally {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    let mut tally = ClientTally {
        ok: 0,
        shed: 0,
        deadline: 0,
        other: 0,
        latencies: Vec::with_capacity(count),
    };
    for i in 0..count {
        let g = generators::erdos_renyi(n, 0.3, seed_base + i as u64);
        let req = Request {
            id: i as u64 + 1,
            graph: g,
            variant: "cpu".into(), // every request costs real solver time
            no_cache: true,        // admission behaviour, not cache behaviour
            want_paths: false,
            objective: "shortest".into(),
            trace: false,
        };
        let line = encode_request_opts(
            &req,
            &WireOptions {
                deadline_ms: Some(10_000),
                binary: false,
            },
        );
        let t0 = Instant::now();
        writer.write_all(line.as_bytes()).expect("send");
        writer.write_all(b"\n").expect("send");
        let mut reply = String::new();
        reader.read_line(&mut reply).expect("reply");
        tally.latencies.push(t0.elapsed().as_secs_f64());
        let v = Json::parse(&reply).expect("reply parses");
        match v.get("type").as_str() {
            Some("result") => tally.ok += 1,
            Some("error") => match v.get("code").as_str() {
                Some(c) if c == coordinator::types::CODE_SHED => tally.shed += 1,
                Some(c) if c == coordinator::types::CODE_DEADLINE_EXCEEDED => {
                    tally.deadline += 1
                }
                _ => tally.other += 1,
            },
            _ => tally.other += 1,
        }
    }
    tally
}

/// Offered load at 1×/4×/10× of pool capacity against a small fixed pool:
/// under capacity nothing sheds; past it the bounded queue sheds with the
/// typed error and tail latency stays flat instead of growing without
/// bound (the whole point of admission control).
fn saturation_section(sink: &mut BenchSink) {
    common::banner("front-end saturation — fixed pool, bounded queue, typed sheds");
    let workers = 2usize;
    let queue_depth = 4usize;
    let coord = Arc::new(synthetic_coordinator());
    let server = Server::spawn_with(
        coord.clone(),
        "127.0.0.1:0",
        ServerConfig {
            workers,
            queue_depth,
            deadline_ms: 30_000,
            idle_timeout_ms: 0,
            ..ServerConfig::default()
        },
    )
    .expect("server");
    let addr = server.addr().to_string();
    let (n, per_client) = if common::fast_mode() { (128, 8) } else { (256, 30) };

    for load in [1usize, 4, 10] {
        let clients = load * workers;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    saturation_client(&addr, n, 10_000 * (c as u64 + 1), per_client)
                })
            })
            .collect();
        let mut ok = 0;
        let mut shed = 0;
        let mut deadline = 0;
        let mut other = 0;
        let mut lat = Samples::new();
        for h in handles {
            let t = h.join().expect("client thread");
            ok += t.ok;
            shed += t.shed;
            deadline += t.deadline;
            other += t.other;
            for s in t.latencies {
                lat.push(s);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let attempts = clients * per_client;
        let shed_rate = shed as f64 / attempts as f64;
        let throughput = ok as f64 / wall;
        let pcts = lat.percentiles(&[50.0, 99.0]);
        let (p50, p99) = (pcts[0], pcts[1]);
        println!(
            "load {load:>2}×  clients {clients:>2}  ok {ok:>3}  shed {shed:>3} \
             ({:>4.0}%)  p50 {}  p99 {}  {throughput:.0} req/s",
            shed_rate * 100.0,
            format_time(p50),
            format_time(p99),
        );
        sink.record_json(Json::obj(vec![
            ("bench", Json::str("saturation")),
            ("load", Json::num(load as f64)),
            ("workers", Json::num(workers as f64)),
            ("queue_depth", Json::num(queue_depth as f64)),
            ("clients", Json::num(clients as f64)),
            ("n", Json::num(n as f64)),
            ("attempts", Json::num(attempts as f64)),
            ("ok", Json::num(ok as f64)),
            ("shed", Json::num(shed as f64)),
            ("deadline_exceeded", Json::num(deadline as f64)),
            ("other_errors", Json::num(other as f64)),
            ("shed_rate", Json::Num(shed_rate)),
            ("throughput_rps", Json::Num(throughput)),
            ("p50_s", Json::Num(p50)),
            ("p99_s", Json::Num(p99)),
        ]));
        if check_mode() {
            assert_eq!(
                ok + shed + deadline + other,
                attempts,
                "every request must come back as a result or a typed error"
            );
            assert_eq!(other, 0, "no untyped errors under saturation");
            if load >= 10 {
                assert!(
                    shed > 0,
                    "10× capacity must trip admission control (ok={ok} shed={shed})"
                );
            }
        }
    }
    server.shutdown();
}

fn serving_sections() {
    // default path BENCH_saturation.json at the repo root (the name CI
    // uploads); FW_BENCH_JSON redirects as usual
    let mut sink = BenchSink::from_env("saturation");
    sink.set_meta("fast", Json::Bool(common::fast_mode()));
    sink.set_meta("kernel", Json::str(fw_stage::apsp::simd::active().name()));
    frame_codec_section(&mut sink);
    saturation_section(&mut sink);
    match sink.finish() {
        Ok(Some(path)) => println!("\nserving trajectory appended: {}", path.display()),
        Ok(None) => println!("\nserving trajectory sink disabled (FW_BENCH_JSON=off)"),
        Err(e) => eprintln!("\nWARN: could not write serving trajectory: {e}"),
    }
}

fn main() {
    if std::env::var("FW_SATURATION_ONLY").map(|v| v == "1").unwrap_or(false) {
        // artifact-free serving smoke: frame codec + saturation only
        serving_sections();
        return;
    }

    superblock_schedule_section();

    let Some(dir) = common::artifact_dir() else {
        println!("(artifacts not built — remaining coordinator benches need `make artifacts`)");
        serving_sections();
        return;
    };

    // ---- request-path overhead: engine round trip vs direct pool call ----
    common::banner("coordinator overhead — direct pool vs engine round-trip vs TCP");
    let n = 128;
    let g = generators::erdos_renyi(n, 0.3, 5);
    let cfg = common::config_for(n);

    let pool = fw_stage::runtime::ExecutorPool::open(&dir).expect("pool");
    pool.solve("staged", &g).expect("warm");
    let direct = bench("direct pool.solve", &cfg, || {
        black_box(pool.solve("staged", &g).expect("solve"));
    });
    println!("direct pool.solve      {}", format_time(direct.median_s));
    drop(pool);

    let mut config = Config::new(&dir);
    config.cache_capacity = 64;
    config.engine.batch_window = Duration::from_millis(0);
    let coord = Arc::new(Coordinator::start(config).expect("coordinator"));
    coord.solve_graph(&g, "staged").expect("warm");
    let engine = bench("coordinator.solve", &cfg, || {
        black_box(
            coord
                .solve(&Request {
                    id: 0,
                    graph: g.clone(),
                    variant: "staged".into(),
                    no_cache: true,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("solve"),
        );
    });
    println!(
        "coordinator.solve      {}   (+{:.1}% vs direct)",
        format_time(engine.median_s),
        (engine.median_s / direct.median_s - 1.0) * 100.0
    );

    let server = Server::spawn(coord.clone(), "127.0.0.1:0").expect("server");
    let addr = server.addr().to_string();
    let mut client = Client::connect(&addr).expect("client");
    // different seeds to dodge the cache; measure full TCP round trip
    let mut tcp = Samples::new();
    for i in 0..10 {
        let g = generators::erdos_renyi(n, 0.3, 1000 + i);
        let t0 = Instant::now();
        client.solve(&g, "staged").expect("tcp solve");
        tcp.push(t0.elapsed().as_secs_f64());
    }
    println!(
        "TCP client.solve       {}   (+{:.1}% vs direct; includes JSON codec)",
        format_time(tcp.median()),
        (tcp.median() / direct.median_s - 1.0) * 100.0
    );

    // ---- cache hit path ----
    common::banner("cache-hit latency");
    let g_cached = generators::erdos_renyi(n, 0.3, 42);
    coord.solve_graph(&g_cached, "staged").expect("prime cache");
    let hit = bench("cache hit", &common::config_for(64), || {
        black_box(
            coord
                .solve(&Request {
                    id: 0,
                    graph: g_cached.clone(),
                    variant: "staged".into(),
                    no_cache: false,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("hit"),
        );
    });
    println!(
        "cache hit              {}   ({:.0}× faster than device solve)",
        format_time(hit.median_s),
        engine.median_s / hit.median_s
    );

    // ---- incremental update path vs full recompute through the stack ----
    // the dynamic-graph tier: a cached (dist, succ) closure is the base
    // state; update requests ship only edge deltas against its fingerprint
    common::banner("incremental update vs recompute — coordinator request path");
    let g_upd = generators::erdos_renyi(n, 0.3, 77);
    coord
        .solve(&Request {
            id: 0,
            graph: g_upd.clone(),
            variant: "staged".into(),
            no_cache: false,
            want_paths: true, // successor-carrying base: increases stay incremental
            objective: "shortest".into(),
            trace: false,
        })
        .expect("prime update base");
    let mut delta = Vec::new();
    'delta: for i in 0..n {
        for j in 0..n {
            if i != j && g_upd.get(i, j).is_finite() {
                delta.push(EdgeUpdate { src: i, dst: j, weight: g_upd.get(i, j) * 0.5 });
                if delta.len() == 4 {
                    break 'delta;
                }
            }
        }
    }
    let fp = graph_fingerprint(&g_upd);
    let upd = bench("coordinator.update (4-edge delta)", &common::config_for(64), || {
        let outcome = coord
            .update(&UpdateRequest {
                id: 0,
                variant: "staged".into(),
                n: g_upd.n(),
                base_fingerprint: fp,
                updates: delta.clone(),
                want_paths: false,
                objective: "shortest".into(),
            })
            .expect("update");
        match outcome {
            UpdateOutcome::Solved(resp) => black_box(resp),
            UpdateOutcome::BaseMissing { .. } => panic!("base evicted mid-bench"),
        };
    });
    let g_upd_mut = incremental::mutated(&g_upd, &delta).expect("valid batch");
    let recompute = bench("full solve of mutated graph", &cfg, || {
        black_box(
            coord
                .solve(&Request {
                    id: 0,
                    graph: g_upd_mut.clone(),
                    variant: "staged".into(),
                    no_cache: true,
                    want_paths: false,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("solve"),
        );
    });
    println!(
        "update (incremental)   {}",
        format_time(upd.median_s)
    );
    println!(
        "recompute (no cache)   {}   (incremental is {:.1}× faster)",
        format_time(recompute.median_s),
        recompute.median_s / upd.median_s
    );

    // short update-heavy trace replay: deltas chain across fingerprints
    let trace = workload::generate(&TraceConfig {
        count: 16,
        ..TraceConfig::update_heavy(0xD17A)
    });
    let mut current: std::collections::HashMap<(usize, u64), fw_stage::graph::DistMatrix> =
        std::collections::HashMap::new();
    let t0 = Instant::now();
    let mut applied = 0u64;
    for item in &trace {
        let key = (item.n, item.seed);
        let base = current.entry(key).or_insert_with(|| item.graph());
        if item.updates.is_empty() {
            coord
                .solve(&Request {
                    id: 0,
                    graph: base.clone(),
                    variant: "staged".into(),
                    no_cache: false,
                    want_paths: true,
                    objective: "shortest".into(),
                    trace: false,
                })
                .expect("trace solve");
            continue;
        }
        let outcome = coord
            .update(&UpdateRequest {
                id: 0,
                variant: "staged".into(),
                n: base.n(),
                base_fingerprint: graph_fingerprint(base),
                updates: item.updates.clone(),
                want_paths: false,
                objective: "shortest".into(),
            })
            .expect("trace update");
        if matches!(outcome, UpdateOutcome::Solved(_)) {
            applied += 1;
        }
        *base = incremental::mutated(base, &item.updates).expect("valid trace batch");
    }
    let trace_s = t0.elapsed().as_secs_f64();
    let snap = coord.metrics().snapshot();
    println!(
        "update-heavy trace     {}   ({applied} chained updates; {} edges, {} recomputes)",
        format_time(trace_s),
        snap.get("update_edges"),
        snap.get("update_recomputes"),
    );

    // ---- batching throughput: packable small graphs vs sequential ----
    // n=30 graphs share the 64 bucket two-at-a-time: the cost-model packer
    // halves the number of device calls (see batcher.rs for why packing
    // never escalates to a larger bucket)
    common::banner("block-diagonal batching — 8 × n=30 concurrent requests");
    let graphs: Vec<_> = (0..8u64)
        .map(|i| generators::erdos_renyi(30, 0.35, 2000 + i))
        .collect();

    // one coordinator for both modes: device route forced (cpu_threshold 0)
    let mut config = Config::new(&dir);
    config.engine.batch_window = Duration::from_millis(5);
    config.router.cpu_threshold = 0; // small graphs must reach the engine
    config.cache_capacity = 0;
    let batching = Arc::new(Coordinator::start(config).expect("coordinator"));
    batching
        .solve_graph(&graphs[0], "staged")
        .expect("warm batching coordinator");

    // sequential: one at a time ⇒ every engine round holds a single job
    let t0 = Instant::now();
    for g in &graphs {
        batching
            .solve(&Request {
                id: 0,
                graph: g.clone(),
                variant: "staged".into(),
                no_cache: true,
                want_paths: false,
                objective: "shortest".into(),
                trace: false,
            })
            .expect("sequential");
    }
    let sequential = t0.elapsed().as_secs_f64();
    let bserver = Server::spawn(batching.clone(), "127.0.0.1:0").expect("server");
    let baddr = bserver.addr().to_string();
    let t0 = Instant::now();
    let handles: Vec<_> = graphs
        .iter()
        .cloned()
        .map(|g| {
            let addr = baddr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("client");
                c.solve(&g, "staged").expect("solve")
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let concurrent = t0.elapsed().as_secs_f64();
    let snap = batching.metrics().snapshot();
    println!("sequential (8 calls)   {}", format_time(sequential));
    println!(
        "batched    (packed)    {}   ({:.2}× speedup)",
        format_time(concurrent),
        sequential / concurrent
    );
    println!(
        "engine batches: {} device calls for {} items",
        snap.get("batches"),
        snap.get("batched_items")
    );

    // ---- super-block tier through the coordinator (device diagonal) ----
    // larger than every artifact bucket: the router sends it to the
    // superblock tier, whose diagonal tiles loop back through the engine
    common::banner("superblock tier — oversize request through the coordinator");
    let n_sb = if common::fast_mode() { 600 } else { 1024 };
    let g_sb = generators::scale_free(n_sb, 2, 77);
    let t0 = Instant::now();
    let resp = batching
        .solve(&Request {
            id: 0,
            graph: g_sb.clone(),
            variant: "staged".into(),
            no_cache: true,
            want_paths: false,
            objective: "shortest".into(),
            trace: false,
        })
        .expect("superblock solve");
    let sb_seconds = t0.elapsed().as_secs_f64();
    println!(
        "coordinator n={n_sb}    {}   (source {}, super-bucket {})",
        format_time(sb_seconds),
        resp.source.name(),
        resp.bucket
    );
    let snap = batching.metrics().snapshot();
    println!(
        "superblock rounds: {}  tile updates: {}",
        snap.get("superblock_rounds"),
        snap.get("superblock_tiles")
    );

    serving_sections();
}

//! Quickstart — the end-to-end driver proving all three layers compose.
//!
//! Loads the AOT artifacts (L1 Pallas kernels lowered through the L2 jax
//! model), starts the L3 coordinator, solves APSP for a real small workload
//! (a 400-vertex scale-free network) on the device path, cross-checks the
//! result against the CPU oracle, and reports the measured tasks/s next to
//! the calibrated C1060 simulation — the headline metric of the paper.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! The run recorded in EXPERIMENTS.md §E2E came from this binary.

use std::time::Instant;

use fw_stage::coordinator::{Config, Coordinator, Request};
use fw_stage::graph::generators;
use fw_stage::simulator::{simulate, Variant};
use fw_stage::{apsp, DEFAULT_TILE};

fn main() -> anyhow::Result<()> {
    // 1. a realistic small workload: scale-free "network analysis" graph
    let n = 400;
    let graph = generators::scale_free(n, 3, 2026);
    println!(
        "workload: scale-free n={} edges={} (≈{:.1} avg degree)",
        graph.n(),
        graph.edge_count(),
        graph.edge_count() as f64 / n as f64
    );

    // 2. the full serving stack: artifacts → PJRT engine → coordinator
    let coord = Coordinator::start(Config::new(fw_stage::runtime::artifact::discover_dir()))?;
    let summary = coord.manifest_summary();
    println!(
        "coordinator up: variants [{}], buckets {:?}, tile {}",
        summary.variants.join(", "),
        summary.buckets,
        summary.tile
    );

    // 3. solve on the device path (staged kernel — the paper's contribution)
    let t0 = Instant::now();
    let resp = coord.solve(&Request {
        id: 1,
        graph: graph.clone(),
        variant: "staged".into(),
        no_cache: true,
        want_paths: false,
        objective: "shortest".into(),
        trace: false,
    })?;
    let device_s = t0.elapsed().as_secs_f64();
    let tasks = (resp.bucket as f64).powi(3);
    println!(
        "device solve: n={n} padded to bucket {} via {} in {:.3}s → {:.3e} tasks/s",
        resp.bucket,
        resp.source.name(),
        device_s,
        tasks / device_s
    );

    // 4. cross-check against the CPU oracle (and time the CPU baselines)
    let t0 = Instant::now();
    let cpu = apsp::naive::solve(&graph);
    let naive_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let blocked = apsp::blocked::solve(&graph, DEFAULT_TILE);
    let blocked_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        resp.dist.allclose(&cpu, 1e-5, 1e-5),
        "device result diverges from CPU oracle by {}",
        resp.dist.max_abs_diff(&cpu)
    );
    anyhow::ensure!(blocked.allclose(&cpu, 1e-5, 1e-5));
    println!(
        "verified vs CPU oracle ✓  (naive {:.3}s, blocked {:.3}s, {:.2}× blocking speedup)",
        naive_s,
        blocked_s,
        naive_s / blocked_s
    );

    // 5. a couple of sanity readouts a network analyst would ask for
    let finite: Vec<f32> = cpu
        .as_slice()
        .iter()
        .copied()
        .filter(|w| w.is_finite() && *w > 0.0)
        .collect();
    let mean = finite.iter().map(|&w| w as f64).sum::<f64>() / finite.len() as f64;
    let diameter = finite.iter().copied().fold(0f32, f32::max);
    println!("network: mean shortest path {mean:.3}, diameter {diameter:.3}");

    // 6. the paper-scale context: what the same kernels model out to on the
    //    paper's testbed (Table 1 headline)
    let sim = simulate(Variant::StagedLoad, 16384);
    println!(
        "simulated C1060 (staged, n=16384): {:.2}s — paper reports 53.02s",
        sim.seconds
    );
    println!("quickstart OK");
    Ok(())
}

//! Routing — APSP as a road-network routing table, with actual paths.
//!
//! The paper's intro motivates APSP with routing.  This example builds a
//! 20×20 grid "road network" (400 intersections), computes the full
//! distance matrix through the serving stack, reconstructs turn-by-turn
//! routes with the successor-matrix solver, and prints a routing-table
//! summary.
//!
//! ```bash
//! make artifacts && cargo run --release --example routing
//! ```

use fw_stage::apsp::paths;
use fw_stage::coordinator::{Config, Coordinator};
use fw_stage::graph::generators;

fn main() -> anyhow::Result<()> {
    let side = 20;
    let graph = generators::grid(side, 7);
    let n = graph.n();
    println!("road network: {side}×{side} grid, {n} intersections, {} road segments", graph.edge_count());

    // distances via the device path
    let coord = Coordinator::start(Config::new(fw_stage::runtime::artifact::discover_dir()))?;
    let dist = coord.solve_graph(&graph, "staged")?;

    // paths via the successor-matrix CPU solver (the device kernel computes
    // distances; route extraction is a coordinator-side feature)
    let routes = paths::solve(&graph);
    anyhow::ensure!(
        routes.dist.allclose(&dist, 1e-4, 1e-4),
        "path solver disagrees with device distances"
    );

    // a few concrete routes across the map
    let corner = 0; // top-left
    let center = (side / 2) * side + side / 2;
    let far = n - 1; // bottom-right
    for (label, from, to) in [
        ("corner → far corner", corner, far),
        ("corner → center", corner, center),
        ("center → far corner", center, far),
    ] {
        let route = routes.path(from, to).expect("grid is connected");
        println!(
            "{label}: cost {:.2}, {} hops, via {:?}...",
            dist.get(from, to),
            route.len() - 1,
            &route[..route.len().min(6)]
        );
    }

    // routing-table statistics
    let mut total = 0f64;
    let mut count = 0usize;
    let mut worst = (0usize, 0usize, 0f32);
    for i in 0..n {
        for j in 0..n {
            let d = dist.get(i, j);
            if i != j && d.is_finite() {
                total += d as f64;
                count += 1;
                if d > worst.2 {
                    worst = (i, j, d);
                }
            }
        }
    }
    println!(
        "routing table: {count} pairs, mean cost {:.3}, worst pair ({}, {}) at {:.3}",
        total / count as f64,
        worst.0,
        worst.1,
        worst.2
    );

    // incremental what-if: close a road (both directions) near the center
    // and measure the re-routed cost — topology changes re-run the solver
    let mut closed = graph.clone();
    closed.set(center, center + 1, f32::INFINITY);
    closed.set(center + 1, center, f32::INFINITY);
    let dist2 = coord.solve_graph(&closed, "staged")?;
    let before = dist.get(corner, far);
    let after = dist2.get(corner, far);
    println!(
        "road closure at center: corner→far cost {before:.3} → {after:.3} ({})",
        if after > before { "detour" } else { "unaffected" }
    );
    println!("routing OK");
    Ok(())
}

//! Serving demo — replay a Poisson workload trace against the TCP server.
//!
//! Starts `fw-stage`'s coordinator + server in-process, replays a
//! heavy-tail trace from concurrent client threads honoring arrival times,
//! and reports throughput, latency percentiles, and the batching/caching
//! metrics the coordinator collected.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use fw_stage::coordinator::{client::Client, server::Server, Config, Coordinator};
use fw_stage::util::stats::Samples;
use fw_stage::workload::{generate, GraphKind, TraceConfig};

fn main() -> anyhow::Result<()> {
    let mut config = Config::new(fw_stage::runtime::artifact::discover_dir());
    config.engine.batch_window = Duration::from_millis(3);
    // FW_STORE_DIR=<dir> attaches the persistent closure store: every
    // closure solved below is persisted, and the demo finishes with a
    // kill-and-restart round trip (see the persistence regime at the end)
    let store_dir = std::env::var("FW_STORE_DIR").ok().filter(|p| !p.is_empty());
    if let Some(dir) = &store_dir {
        config.store = Some(fw_stage::coordinator::store::StoreConfig {
            dir: dir.into(),
            max_bytes: 0,
        });
    }
    let coord = Arc::new(Coordinator::start(config)?);
    let server = Server::spawn(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr().to_string();
    println!("server on {addr}");

    let trace = generate(&TraceConfig {
        rate_hz: 60.0,
        count: 120,
        sizes: vec![40, 60, 100, 120, 200],
        heavy_tail: true,
        kinds: vec![GraphKind::ErdosRenyi, GraphKind::Grid, GraphKind::ScaleFree],
        seed: 0xBEEF,
        ..TraceConfig::default()
    });
    let span = trace.last().unwrap().at.as_secs_f64();
    println!("trace: {} requests over {span:.2}s (heavy-tail sizes)", trace.len());

    // replay with a small client fleet; each client owns a slice of the trace
    let clients = 6;
    let start = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let items: Vec<_> = trace
                .iter()
                .skip(c)
                .step_by(clients)
                .cloned()
                .collect();
            std::thread::spawn(move || -> anyhow::Result<Samples> {
                let mut client = Client::connect(&addr)?;
                let mut lat = Samples::new();
                for item in items {
                    // honor the arrival schedule
                    let now = start.elapsed();
                    if item.at > now {
                        std::thread::sleep(item.at - now);
                    }
                    let g = item.graph();
                    let t0 = Instant::now();
                    let resp = client.solve(&g, "staged")?;
                    lat.push(t0.elapsed().as_secs_f64());
                    anyhow::ensure!(resp.dist.n() == g.n());
                }
                Ok(lat)
            })
        })
        .collect();

    let mut all = Samples::new();
    for h in handles {
        let lat = h.join().expect("client thread")?;
        all.merge(&lat);
    }
    let wall = start.elapsed().as_secs_f64();
    println!(
        "replayed {} requests in {wall:.2}s → {:.1} req/s",
        trace.len(),
        trace.len() as f64 / wall
    );
    println!(
        "latency: p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms  max {:.1}ms",
        all.percentile(50.0) * 1e3,
        all.percentile(90.0) * 1e3,
        all.percentile(99.0) * 1e3,
        all.max() * 1e3,
    );

    let snapshot = coord.metrics().snapshot();
    println!("coordinator metrics: {snapshot}");
    let batches = snapshot.get("batches").as_f64().unwrap_or(0.0);
    let items = snapshot.get("batched_items").as_f64().unwrap_or(0.0);
    if batches > 0.0 {
        println!(
            "batching: {items:.0} device items in {batches:.0} calls (avg {:.2} per call)",
            items / batches
        );
    }

    // ---- large-n regime: every request overflows the device buckets ----
    // the router sends these to the superblock tier; the trace stays
    // sparse (road-network-shaped) so the wire codec is not the bottleneck
    let large = generate(&TraceConfig {
        count: 4,
        ..TraceConfig::large_n(0xF00D)
    });
    println!(
        "large-n trace: {} requests, n in {:?}",
        large.len(),
        large.iter().map(|t| t.n).collect::<Vec<_>>()
    );
    let mut client = Client::connect(&addr)?;
    let mut large_lat = Samples::new();
    for item in &large {
        let g = item.graph();
        let t0 = Instant::now();
        let resp = client.solve(&g, "staged")?;
        large_lat.push(t0.elapsed().as_secs_f64());
        anyhow::ensure!(resp.dist.n() == g.n());
        println!(
            "  n={:<5} served via {:<10} (super-bucket {}) in {:.2}s",
            g.n(),
            resp.source.name(),
            resp.bucket,
            resp.seconds
        );
    }
    println!(
        "large-n latency: p50 {:.2}s  p95 {:.2}s  p99 {:.2}s",
        large_lat.percentile(50.0),
        large_lat.percentile(95.0),
        large_lat.percentile(99.0),
    );
    let snapshot = coord.metrics().snapshot();
    println!(
        "superblock: {} solves, {} rounds, {} tile updates",
        snapshot.get("superblock_solves"),
        snapshot.get("superblock_rounds"),
        snapshot.get("superblock_tiles")
    );

    // ---- update-heavy regime: edge-delta traffic over cached closures ----
    // base graphs are solved once (with paths, so increases stay
    // incremental); every later item ships only a delta batch against the
    // running graph, exercising the coordinator's fingerprint chains
    let updates = generate(&TraceConfig {
        count: 24,
        ..TraceConfig::update_heavy(0xCAFE)
    });
    let mut current: std::collections::HashMap<(usize, u64), fw_stage::graph::DistMatrix> =
        std::collections::HashMap::new();
    let mut update_lat = Samples::new();
    let mut served_incremental = 0u64;
    for item in &updates {
        let key = (item.n, item.seed);
        let base = current.entry(key).or_insert_with(|| item.graph());
        if item.updates.is_empty() {
            client.solve_paths(base, "staged")?;
            continue;
        }
        let t0 = Instant::now();
        let resp = client.update_or_solve(base, &item.updates, "staged", false)?;
        update_lat.push(t0.elapsed().as_secs_f64());
        if resp.source == fw_stage::coordinator::Source::Incremental {
            served_incremental += 1;
        }
        // chase the chain: the next delta applies to the mutated graph
        *base = fw_stage::apsp::incremental::mutated(base, &item.updates)
            .map_err(anyhow::Error::msg)?;
    }
    println!(
        "update regime: {} delta batches, {} served incrementally, p50 {:.2}ms",
        update_lat.len(),
        served_incremental,
        update_lat.percentile(50.0) * 1e3,
    );
    let snapshot = coord.metrics().snapshot();
    println!(
        "incremental: {} solves, {} edges applied, {} recomputes",
        snapshot.get("incremental_solves"),
        snapshot.get("update_edges"),
        snapshot.get("update_recomputes")
    );

    // ---- objective regime: the same wire, a different semiring ----
    // bottleneck (widest-path) requests ride the identical trace machinery;
    // the router keeps them off the device artifacts (CPU blocked tier) and
    // the cache keys them separately from any shortest-path closure of the
    // same graph
    let widest = generate(&TraceConfig {
        count: 8,
        sizes: vec![40, 60, 100],
        ..TraceConfig::bottleneck(0xD1CE)
    });
    let mut obj_lat = Samples::new();
    for item in &widest {
        let g = item.graph();
        let t0 = Instant::now();
        let resp = client.solve_objective(&g, "staged", &item.objective)?;
        obj_lat.push(t0.elapsed().as_secs_f64());
        anyhow::ensure!(resp.dist.n() == g.n());
        // a bottleneck closure carries +inf on the diagonal (the semiring's
        // multiplicative identity) — cheap proof the right algebra ran
        anyhow::ensure!(resp.dist.get(0, 0).is_infinite());
    }
    println!(
        "bottleneck regime: {} requests, p50 {:.2}ms (served off-device)",
        obj_lat.len(),
        obj_lat.percentile(50.0) * 1e3,
    );

    // ---- observability regime: traces, histograms, exposition ----
    // a traced solve round-trips the request's span tree on the result line
    let g = fw_stage::graph::generators::erdos_renyi(48, 0.3, 0xB0B);
    let (resp, span_tree) = client.solve_traced(&g, "staged")?;
    anyhow::ensure!(resp.dist.n() == g.n());
    anyhow::ensure!(span_tree.get("name").as_str() == Some("request"));
    let child_spans = span_tree.get("spans").as_arr().map(<[_]>::len).unwrap_or(0);
    anyhow::ensure!(child_spans > 0, "trace echo has no child spans");
    println!(
        "traced solve: {child_spans} child spans, root {:.2}ms",
        span_tree.get("seconds").as_f64().unwrap_or(0.0) * 1e3
    );
    // the journal serves the same trees back over the wire, newest first
    let journal = client.trace(4, None, None)?;
    anyhow::ensure!(journal.get("type").as_str() == Some("trace"));
    anyhow::ensure!(journal.get("count").as_usize().unwrap_or(0) >= 1);
    let newest = &journal.get("traces").as_arr().unwrap()[0];
    anyhow::ensure!(newest.get("root").get("name").as_str() == Some("request"));
    // FW_TRACE_JSON=<path> dumps a deeper journal listing to disk (CI
    // uploads it next to the perf trajectory), mirroring FW_BENCH_JSON
    if let Ok(path) = std::env::var("FW_TRACE_JSON") {
        if !path.is_empty() {
            std::fs::write(&path, client.trace(64, None, None)?.to_string())?;
            println!("trace journal written to {path}");
        }
    }
    // stats break latency out per (source, objective) and errors per code
    let snapshot = coord.metrics().snapshot();
    let hist_keys = snapshot
        .get("latency_hist")
        .as_obj()
        .map(|m| m.keys().cloned().collect::<Vec<_>>())
        .unwrap_or_default();
    anyhow::ensure!(!hist_keys.is_empty(), "stats carry no latency histograms");
    anyhow::ensure!(snapshot.get("errors_by_code").as_obj().is_some());
    println!("latency histograms: {hist_keys:?}");
    // the Prometheus text exposition round-trips through its own parser
    let text = client.exposition()?;
    let series = fw_stage::obs::hist::parse_exposition(&text).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        series.keys().any(|k| k.starts_with("fw_request_seconds")),
        "exposition is missing the request-latency histogram"
    );
    // feed the live serving histograms to the perf-trajectory sink: the
    // same BENCH_<name>.json machinery `cargo bench` uses, so CI keeps a
    // row of real end-to-end latency distributions per run
    let mut sink = fw_stage::perf::BenchSink::from_env("serve_live");
    sink.set_meta("mode", fw_stage::util::json::Json::str("serve_demo"));
    sink.set_meta(
        "requests",
        fw_stage::util::json::Json::num(trace.len() as f64),
    );
    for (key, h) in &series {
        sink.record_json(h.to_bench_json(key));
    }
    if let Some(path) = sink.finish()? {
        println!("live histogram rows appended to {}", path.display());
    }
    println!("observability: trace echo + journal + exposition round-trip verified");

    // ---- persistence regime: kill the server, warm-start from disk ----
    // only with FW_STORE_DIR set.  Generation 1 (everything above) has
    // persisted each solved closure write-behind; generation 2 must serve
    // replayed graphs from the store — bitwise identical, zero re-solves.
    if let Some(dir) = &store_dir {
        // settle the write-behind queue, then prove each replay graph is
        // actually on disk before tearing generation 1 down
        coord.flush_store();
        let store = coord.store().expect("store was configured");
        let mut replay: Vec<(fw_stage::graph::DistMatrix, fw_stage::graph::DistMatrix)> =
            Vec::new();
        let mut seen = std::collections::HashSet::new();
        for item in &trace {
            let g = item.graph();
            let fp = fw_stage::coordinator::cache::graph_fingerprint(&g);
            if !seen.insert(fp) {
                continue;
            }
            let entry = store
                .get("staged", g.n(), fp)
                .ok_or_else(|| anyhow::anyhow!("closure {fp:016x} missing from the store"))?;
            replay.push((g, entry.dist));
            if replay.len() >= 8 {
                break;
            }
        }
        let index_json = store.index_json().to_string();
        drop(client);
        drop(server); // generation 1 dies here
        drop(coord);

        // generation 2: same artifacts, same store directory, and a cache
        // far smaller than the replay set — most replays must read through
        // to disk rather than ride the boot warm-start
        let mut config2 = Config::new(fw_stage::runtime::artifact::discover_dir());
        config2.cache_capacity = 4;
        config2.store = Some(fw_stage::coordinator::store::StoreConfig {
            dir: dir.into(),
            max_bytes: 0,
        });
        let coord2 = Coordinator::start(config2)?;
        for (g, dist_gen1) in &replay {
            let resp = coord2.solve(&fw_stage::coordinator::Request {
                id: 0,
                graph: g.clone(),
                variant: "staged".into(),
                no_cache: false,
                want_paths: false,
                objective: "shortest".into(),
                trace: false,
            })?;
            anyhow::ensure!(
                resp.source == fw_stage::coordinator::Source::Cache,
                "replayed graph re-solved via {} after restart",
                resp.source.name()
            );
            for (a, b) in resp.dist.as_slice().iter().zip(dist_gen1.as_slice()) {
                anyhow::ensure!(
                    a.to_bits() == b.to_bits(),
                    "restart served a non-bitwise-identical closure"
                );
            }
        }
        let snap = coord2.metrics().snapshot();
        let counter =
            |key: &str| -> u64 { snap.get(key).as_f64().unwrap_or(0.0) as u64 };
        anyhow::ensure!(counter("store_hits") > 0, "restart never touched the store");
        anyhow::ensure!(counter("store_corrupt") == 0, "store reported corruption");
        anyhow::ensure!(
            counter("cpu_solves") == 0
                && counter("device_solves") == 0
                && counter("superblock_solves") == 0
                && counter("incremental_solves") == 0,
            "generation 2 re-solved a replayed graph"
        );
        // CI artifacts: the store's index and the restart's metrics
        std::fs::write("store_index.json", index_json)?;
        std::fs::write("store_metrics.json", snap.to_string())?;
        println!(
            "persistence: {} closures replayed bitwise from {} after restart \
             (store_hits {}, zero re-solves)",
            replay.len(),
            dir,
            counter("store_hits"),
        );
    }

    println!("serve_demo OK");
    Ok(())
}

//! Network analysis — closeness & harmonic centrality from APSP.
//!
//! The third workload the paper motivates: identify the most central hubs
//! of a scale-free network.  Closeness centrality needs the full distance
//! matrix — exactly what the APSP service provides — and is a one-liner on
//! top of it.
//!
//! ```bash
//! make artifacts && cargo run --release --example centrality
//! ```

use fw_stage::coordinator::{Config, Coordinator};
use fw_stage::graph::generators;

fn main() -> anyhow::Result<()> {
    let n = 500;
    let graph = generators::scale_free(n, 2, 99);
    println!(
        "network: scale-free n={n}, {} edges",
        graph.edge_count() / 2 // symmetric
    );

    let coord = Coordinator::start(Config::new(fw_stage::runtime::artifact::discover_dir()))?;
    let dist = coord.solve_graph(&graph, "staged")?;

    // harmonic centrality: C(i) = Σ_j 1/d(i,j) — robust to disconnection
    // closeness centrality: C(i) = (reachable-1) / Σ_j d(i,j)
    let mut scores: Vec<(usize, f64, f64, usize)> = (0..n)
        .map(|i| {
            let mut harmonic = 0f64;
            let mut total = 0f64;
            let mut reach = 0usize;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let d = dist.get(i, j);
                if d.is_finite() && d > 0.0 {
                    harmonic += 1.0 / d as f64;
                    total += d as f64;
                    reach += 1;
                }
            }
            let closeness = if total > 0.0 { reach as f64 / total } else { 0.0 };
            (i, harmonic, closeness, reach)
        })
        .collect();
    scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    println!("top 10 hubs by harmonic centrality:");
    println!("{:>6} {:>12} {:>12} {:>10} {:>8}", "vertex", "harmonic", "closeness", "reachable", "degree");
    for &(i, harmonic, closeness, reach) in scores.iter().take(10) {
        let degree = (0..n)
            .filter(|&j| j != i && graph.get(i, j).is_finite())
            .count();
        println!("{i:>6} {harmonic:>12.3} {closeness:>12.4} {reach:>10} {degree:>8}");
    }

    // scale-free sanity: hub centrality should correlate with degree
    let top_degree: Vec<usize> = {
        let mut by_degree: Vec<(usize, usize)> = (0..n)
            .map(|i| {
                (
                    i,
                    (0..n).filter(|&j| j != i && graph.get(i, j).is_finite()).count(),
                )
            })
            .collect();
        by_degree.sort_by(|a, b| b.1.cmp(&a.1));
        by_degree.iter().take(10).map(|&(i, _)| i).collect()
    };
    let top_central: Vec<usize> = scores.iter().take(10).map(|&(i, ..)| i).collect();
    let overlap = top_central
        .iter()
        .filter(|i| top_degree.contains(i))
        .count();
    println!("top-10 centrality ∩ top-10 degree: {overlap}/10");
    anyhow::ensure!(overlap >= 3, "hubs should be central in a scale-free net");
    println!("centrality OK");
    Ok(())
}

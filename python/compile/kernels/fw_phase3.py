"""Phase-3 Pallas kernels: the "doubly dependent blocks" — the hot path.

Θ(n²) of the n²/s² tiles per stage are doubly dependent; this phase is Θ(n³)
of the total Θ(n³) work, so (paper §3.2) "it is the efficiency with which
this stage is performed that determines the speed of the algorithm".

Both dependencies (the column-panel tile C and the row-panel tile R) are
final when phase 3 runs, so the k loop is a pure (min, +) matmul and can run
in any order — the property the paper exploits twice: for the cyclic
bank-conflict-free schedule, and for staging the k-range.

Two variants, mirroring the paper's §3.2 vs §4:

``phase3_monolithic`` — the Katz–Kider analog.  One grid step per output
    tile; the full (s, s) C and R tiles are VMEM blocks for the whole step —
    the analog of 3 tiles × 32² words in shared memory (12320 B/block ⇒ one
    thread block per SM ⇒ exposed latency).

``phase3_staged`` — the paper's multi-stage kernel.  k becomes the innermost
    *grid* dimension: each step sees only an (s, m) slice of C and an (m, s)
    slice of R (the analog of 2·t·m words = 1056 B of shared memory), while
    the output tile persists in VMEM across the k steps (the analog of the
    doubly-dependent tile living in registers, §4.1).  The BlockSpec is the
    HBM↔VMEM schedule the CUDA kernel expressed with __syncthreads() stages.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus(c: jax.Array, r: jax.Array) -> jax.Array:
    """out[i, j] = min_k c[i, k] + r[k, j]   (vectorized, order-free)."""
    return jnp.min(c[:, :, None] + r[None, :, :], axis=1)


def _mono_kernel(w_ref, c_ref, r_ref, o_ref):
    o_ref[...] = jnp.minimum(w_ref[...], _minplus(c_ref[...], r_ref[...]))


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def phase3_monolithic(
    w: jax.Array,
    colp: jax.Array,
    rowp: jax.Array,
    *,
    s: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """Katz–Kider-style phase 3: full panel tiles resident per grid step.

    ``w``: (n, n) matrix; ``colp``: (n, s) final column panel; ``rowp``:
    (s, n) final row panel.  Returns the relaxed matrix.
    """
    n = w.shape[0]
    assert w.shape == (n, n) and colp.shape == (n, s) and rowp.shape == (s, n)
    assert n % s == 0
    nb = n // s
    return pl.pallas_call(
        _mono_kernel,
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((s, s), lambda i, j: (i, j)),  # W tile
            pl.BlockSpec((s, s), lambda i, j: (i, 0)),  # C: col-panel tile, row i
            pl.BlockSpec((s, s), lambda i, j: (0, j)),  # R: row-panel tile, col j
        ],
        out_specs=pl.BlockSpec((s, s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), w.dtype),
        interpret=interpret,
    )(w, colp, rowp)


def _staged_kernel(w_ref, c_ref, r_ref, o_ref):
    """One k-stage: relax the resident output tile with an (s,m)x(m,s) slice.

    ``o_ref`` is revisited across the k grid dimension (its index_map ignores
    k), so it acts as the register accumulator of paper §4.1; the first k
    step seeds it from W.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _seed():
        o_ref[...] = w_ref[...]

    o_ref[...] = jnp.minimum(o_ref[...], _minplus(c_ref[...], r_ref[...]))


@functools.partial(jax.jit, static_argnames=("s", "m", "interpret"))
def phase3_staged(
    w: jax.Array,
    colp: jax.Array,
    rowp: jax.Array,
    *,
    s: int = 32,
    m: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """The paper's staged phase 3 (§4.2): k as the innermost grid dimension.

    Per grid step only an (s, m) slice of the column panel and an (m, s)
    slice of the row panel are resident — 2·s·m words, the paper's 1056-byte
    shared-memory footprint — while the (s, s) output tile persists across
    the s/m stages (register-resident tile, §4.1).

    ``m`` is the k-chunk; the paper uses s=32 staged over 4 iterations
    (m=8).  Ablatable via the ``m`` argument (benches E8).
    """
    n = w.shape[0]
    assert w.shape == (n, n) and colp.shape == (n, s) and rowp.shape == (s, n)
    assert n % s == 0 and s % m == 0
    nb, nk = n // s, s // m
    return pl.pallas_call(
        _staged_kernel,
        grid=(nb, nb, nk),  # k innermost: output tile stays resident
        in_specs=[
            pl.BlockSpec((s, s), lambda i, j, k: (i, j)),  # W tile (read at k=0)
            pl.BlockSpec((s, m), lambda i, j, k: (i, k)),  # C slice
            pl.BlockSpec((m, s), lambda i, j, k: (k, j)),  # R slice
        ],
        out_specs=pl.BlockSpec((s, s), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), w.dtype),
        interpret=interpret,
    )(w, colp, rowp)

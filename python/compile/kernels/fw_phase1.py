"""Phase-1 Pallas kernel: the "independent block" (paper §3.2, Fig. 2 l.3-10).

One stage's diagonal tile is a self-contained FW problem: every task in the
tile depends only on other tasks in the same tile (or prior stages).  The k
loop is a true FW recurrence and must run sequentially.

TPU mapping (DESIGN.md §Hardware-Adaptation): the whole (s, s) tile is one
VMEM block; the sequential k loop is a ``fori_loop`` over the value held in
vector registers — the analog of the CUDA kernel keeping the tile in shared
memory for 32 sequential relaxation rounds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _phase1_kernel(w_ref, o_ref):
    """In-VMEM FW over one tile.  Sequential k (true dependency chain)."""
    s = w_ref.shape[0]
    t = w_ref[...]

    def body(k, t):
        # w[i, j] <- min(w[i, j], w[i, k] + w[k, j]) over the whole tile at
        # once: rank-1 (min, +) update, fully vectorized on the VPU.
        return jnp.minimum(t, t[:, k, None] + t[k, None, :])

    o_ref[...] = jax.lax.fori_loop(0, s, body, t)


@functools.partial(jax.jit, static_argnames=("interpret",))
def phase1(diag: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Run FW to fixed point (over its own k-range) on one diagonal tile.

    ``diag``: (s, s) float32.  Returns the closed tile.
    """
    s = diag.shape[0]
    assert diag.shape == (s, s), f"diag must be square, got {diag.shape}"
    return pl.pallas_call(
        _phase1_kernel,
        out_shape=jax.ShapeDtypeStruct((s, s), diag.dtype),
        interpret=interpret,
    )(diag)

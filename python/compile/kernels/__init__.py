"""L1: Pallas kernels for the staged blocked Floyd-Warshall (Lund & Smith 2010).

Phase kernels mirror the paper's three CUDA kernels per stage; ``fw_naive``
is the Harish–Narayanan baseline; ``ref`` is the pure-jnp oracle.
"""

from compile.kernels.fw_naive import naive_jnp, naive_pallas
from compile.kernels.fw_phase1 import phase1
from compile.kernels.fw_phase2 import phase2_col, phase2_row
from compile.kernels.fw_phase3 import phase3_monolithic, phase3_staged

__all__ = [
    "naive_jnp",
    "naive_pallas",
    "phase1",
    "phase2_col",
    "phase2_row",
    "phase3_monolithic",
    "phase3_staged",
]

"""Phase-2 Pallas kernels: the "singly dependent blocks" (paper §3.2).

Each stage has Θ(n/s) singly-dependent tiles aligned with the independent
(diagonal) block in the i- or j-direction.  Each such tile has one
dependency in itself and one in the already-final diagonal tile, so its k
loop is still sequential — but tiles along the panel are independent of each
other, which is what the grid dimension expresses.

TPU mapping: the diagonal tile rides along in VMEM for every grid step
(constant index_map) — the analog of the CUDA kernel keeping the independent
block in shared memory while each thread block owns one panel tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_kernel(d_ref, p_ref, o_ref):
    """i-aligned (row-panel) tile: w[i,j] <- min(w[i,j], d[i,k] + w[k,j])."""
    s = d_ref.shape[0]
    d = d_ref[...]

    def body(k, t):
        return jnp.minimum(t, d[:, k, None] + t[k, None, :])

    o_ref[...] = jax.lax.fori_loop(0, s, body, p_ref[...])


def _col_kernel(d_ref, p_ref, o_ref):
    """j-aligned (col-panel) tile: w[i,j] <- min(w[i,j], w[i,k] + d[k,j])."""
    s = d_ref.shape[0]
    d = d_ref[...]

    def body(k, t):
        return jnp.minimum(t, t[:, k, None] + d[k, None, :])

    o_ref[...] = jax.lax.fori_loop(0, s, body, p_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def phase2_row(diag: jax.Array, panel: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Update the full i-aligned row panel.

    ``diag``: (s, s) final independent block; ``panel``: (s, n) rows of W in
    the stage's k-range.  Grid over the n/s tiles of the panel.
    """
    s = diag.shape[0]
    n = panel.shape[1]
    assert panel.shape == (s, n) and n % s == 0
    return pl.pallas_call(
        _row_kernel,
        grid=(n // s,),
        in_specs=[
            pl.BlockSpec((s, s), lambda j: (0, 0)),  # diag: resident every step
            pl.BlockSpec((s, s), lambda j: (0, j)),  # panel tile j
        ],
        out_specs=pl.BlockSpec((s, s), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), panel.dtype),
        interpret=interpret,
    )(diag, panel)


@functools.partial(jax.jit, static_argnames=("interpret",))
def phase2_col(diag: jax.Array, panel: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Update the full j-aligned column panel.

    ``diag``: (s, s) final independent block; ``panel``: (n, s) columns of W
    in the stage's k-range.  Grid over the n/s tiles of the panel.
    """
    s = diag.shape[0]
    n = panel.shape[0]
    assert panel.shape == (n, s) and n % s == 0
    return pl.pallas_call(
        _col_kernel,
        grid=(n // s,),
        in_specs=[
            pl.BlockSpec((s, s), lambda i: (0, 0)),
            pl.BlockSpec((s, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((s, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, s), panel.dtype),
        interpret=interpret,
    )(diag, panel)

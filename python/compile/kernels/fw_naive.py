"""Naive one-task-per-element FW — the Harish & Narayanan baseline (paper §3.1).

H&N launch one CUDA thread per (i, j) element for each k: every task moves
16 bytes over the global-memory bus (3 loads + 1 store), so the kernel is
bandwidth-bound.  The XLA analog is a k-sequential whole-matrix rank-1
relaxation: every k step streams the full matrix HBM→compute→HBM, exactly
the traffic pattern that saturates the bus in the paper's measurement
(42 GB/s achieved of 77 GB/s, §5).

Two forms are provided:

``naive_jnp``   — pure jnp/lax (what H&N's grid launch lowers to under XLA).
``naive_pallas``— the same schedule expressed as a Pallas kernel with k as
                  the grid: one grid step = one CUDA kernel launch, the full
                  matrix as the block (no on-chip reuse).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def naive_jnp(w: jax.Array) -> jax.Array:
    """k-sequential full-matrix relaxation; identical to ref.floyd_warshall
    but kept here as the lowering target for the 'naive' artifact variant."""
    n = w.shape[0]

    def body(k, w):
        row = jax.lax.dynamic_slice_in_dim(w, k, 1, axis=0)
        col = jax.lax.dynamic_slice_in_dim(w, k, 1, axis=1)
        return jnp.minimum(w, col + row)

    return jax.lax.fori_loop(0, n, body, w)


def _naive_kernel(w_ref, o_ref):
    """One k iteration over the full matrix.

    The output ref is revisited across the k grid (index_map ignores k), so
    step k reads the result of step k-1 — the same global-memory round trip
    per iteration H&N's repeated kernel launches make.
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _seed():
        o_ref[...] = w_ref[...]

    t = o_ref[...]
    row = jax.lax.dynamic_slice_in_dim(t, k, 1, axis=0)  # (1, n)
    col = jax.lax.dynamic_slice_in_dim(t, k, 1, axis=1)  # (n, 1)
    o_ref[...] = jnp.minimum(t, col + row)


@functools.partial(jax.jit, static_argnames=("interpret",))
def naive_pallas(w: jax.Array, *, interpret: bool = True) -> jax.Array:
    """H&N-style FW: grid over k, whole matrix per step, no blocking."""
    n = w.shape[0]
    assert w.shape == (n, n)
    return pl.pallas_call(
        _naive_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((n, n), lambda k: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), w.dtype),
        interpret=interpret,
    )(w)

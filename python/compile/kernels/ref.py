"""Pure-jnp correctness oracles for the Floyd-Warshall kernels.

Everything in this module is *reference* code: simple, obviously-correct
implementations of the recurrences the Pallas kernels (fw_phase*.py) and the
blocked composition (model.py) must match.  Used only by pytest — never
lowered into artifacts.

The FW recurrence (paper Fig. 1):

    w[i, j] <- min(w[i, j], w[i, k] + w[k, j])   for k = 0 .. n-1 (sequential)

and its blocked decomposition (paper Fig. 2): per stage ``b`` process the
independent (diagonal) block, then the singly-dependent row/column panels,
then the doubly-dependent remainder, where only the last has a reorderable
(min-plus matmul) k loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def floyd_warshall(w: jax.Array) -> jax.Array:
    """Textbook FW over a dense (n, n) distance matrix.  O(n^3), jittable."""
    n = w.shape[0]

    def body(k, w):
        row = jax.lax.dynamic_slice_in_dim(w, k, 1, axis=0)  # (1, n)
        col = jax.lax.dynamic_slice_in_dim(w, k, 1, axis=1)  # (n, 1)
        return jnp.minimum(w, col + row)

    return jax.lax.fori_loop(0, n, body, w)


def floyd_warshall_numpy(w: np.ndarray) -> np.ndarray:
    """Loop-over-k FW in numpy.  The slowest, most obviously correct oracle."""
    w = w.copy()
    n = w.shape[0]
    for k in range(n):
        w = np.minimum(w, w[:, k : k + 1] + w[k : k + 1, :])
    return w


def min_plus_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(min, +) matrix product: out[i, j] = min_k a[i, k] + b[k, j].

    This is the order-free phase-3 inner computation (paper §3.2: "these
    tasks may be performed in any order").
    """
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def fw_tile_inplace(t: jax.Array) -> jax.Array:
    """Phase-1 recurrence: full FW restricted to one tile (sequential k)."""
    s = t.shape[0]

    def body(k, t):
        return jnp.minimum(t, t[:, k, None] + t[k, None, :])

    return jax.lax.fori_loop(0, s, body, t)


def fw_row_panel(diag: jax.Array, panel: jax.Array) -> jax.Array:
    """Phase-2 recurrence for an i-aligned (row) panel.

    ``panel`` is (s, n): the rows of W in the current k-range.  Dependency
    w[i, k] lives in the (final) diagonal tile, w[k, j] in the panel itself,
    so k must advance sequentially (paper Fig. 2 lines 12-21).
    """
    s = diag.shape[0]

    def body(k, p):
        return jnp.minimum(p, diag[:, k, None] + p[k, None, :])

    return jax.lax.fori_loop(0, s, body, panel)


def fw_col_panel(diag: jax.Array, panel: jax.Array) -> jax.Array:
    """Phase-2 recurrence for a j-aligned (column) panel.

    ``panel`` is (n, s): the columns of W in the current k-range.  Dependency
    w[i, k] is in the panel itself, w[k, j] in the diagonal tile
    (paper Fig. 2 lines 22-31).
    """
    s = diag.shape[0]

    def body(k, p):
        return jnp.minimum(p, p[:, k, None] + diag[k, None, :])

    return jax.lax.fori_loop(0, s, body, panel)


def blocked_floyd_warshall(w: jax.Array, s: int) -> jax.Array:
    """Reference blocked FW (paper Fig. 2) built from the recurrences above.

    Python-level stage loop (unrolled at trace time); each phase uses the
    reference tile/panel functions.  Phase 3 relaxes the *entire* matrix with
    the final panels — re-relaxing panel elements is a no-op because min-plus
    relaxation against valid path lengths is conservative (DESIGN.md,
    "Algorithm correctness note").
    """
    n = w.shape[0]
    assert n % s == 0, f"n={n} not a multiple of tile size s={s}"
    for b in range(n // s):
        ks = b * s
        diag = fw_tile_inplace(w[ks : ks + s, ks : ks + s])
        w = w.at[ks : ks + s, ks : ks + s].set(diag)
        rowp = fw_row_panel(diag, w[ks : ks + s, :])
        w = w.at[ks : ks + s, :].set(rowp)
        colp = fw_col_panel(diag, w[:, ks : ks + s])
        w = w.at[:, ks : ks + s].set(colp)
        w = jnp.minimum(w, min_plus_matmul(colp, rowp))
    return w


def random_distance_matrix(
    n: int,
    *,
    density: float = 0.4,
    key: jax.Array | None = None,
    seed: int = 0,
    max_weight: float = 10.0,
) -> jax.Array:
    """Random directed-graph distance matrix: diag 0, ``density`` fraction of
    finite off-diagonal edges, rest +inf.  Used by tests and benches.
    """
    if key is None:
        key = jax.random.PRNGKey(seed)
    kw, km = jax.random.split(key)
    weights = jax.random.uniform(kw, (n, n), minval=0.1, maxval=max_weight)
    mask = jax.random.uniform(km, (n, n)) < density
    w = jnp.where(mask, weights, jnp.inf)
    w = w.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    return w.astype(jnp.float32)

"""AOT lowering: jax/Pallas → HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``apsp_<variant>_n<n>.hlo.txt`` per (variant × size), plus
``manifest.json`` describing every artifact (shape, dtype, variant, tile,
kchunk) so the Rust side can discover and validate them without guessing.
Python never runs again after this.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from compile.model import DEFAULT_KCHUNK, DEFAULT_TILE, VARIANTS, apsp_fn

# Default deployment matrix: every variant at every serving bucket size.
# Sizes are the coordinator's padding buckets (powers of two × tile).
DEFAULT_SIZES = (64, 128, 256, 512)
# Ablation artifacts (E8): the paper stages t=32 over 4 iterations (m=8);
# we also ship m ∈ {4, 16, 32} for the staged variant at one probe size.
ABLATION_KCHUNKS = (4, 16, 32)
ABLATION_SIZE = 256

MANIFEST_VERSION = 2


def tuned_params(n: int, tile: int, kchunk: int) -> tuple[int, int]:
    """Per-size tile/k-chunk tuning (§Perf, EXPERIMENTS.md).

    The paper's 32×32/m=8 is sized for the C1060's 16 KB shared memory; the
    TPU-model adaptation has VMEM-scale (~16 MB) tiles, and on the XLA-CPU
    substrate grid-step overhead dominates, so larger tiles win heavily
    (measured 17× at n=512: tile 128/m 32 vs 32/8).  We keep the paper's
    4-stage structure (m = tile/4) and scale the tile with the problem:
    tile = clamp(n/2, 32, 128).
    """
    t = min(128, max(32, n // 2))
    t = min(t, n)  # never exceed the matrix
    return t, max(1, t // 4)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(variant: str, n: int, tile: int, kchunk: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jax.numpy.float32)
    fn = apsp_fn(variant, n, tile=tile, kchunk=kchunk)
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


def build(
    out_dir: pathlib.Path,
    sizes: tuple[int, ...],
    variants: tuple[str, ...],
    tile: int,
    kchunk: int,
    with_ablations: bool,
    verbose: bool = True,
    tune: bool = False,
) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    entries = []

    def emit(variant: str, n: int, t: int, m: int, tag: str = ""):
        name = f"apsp_{variant}_n{n}{tag}.hlo.txt"
        t0 = time.time()
        text = lower_one(variant, n, t, m)
        path = out_dir / name
        path.write_text(text)
        entry = {
            "name": name,
            "variant": variant,
            "n": n,
            "tile": t,
            "kchunk": m if variant == "staged" else None,
            "dtype": "f32",
            "input_shape": [n, n],
            "output_shape": [n, n],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        entries.append(entry)
        if verbose:
            print(
                f"  {name:40s} {len(text):>10d} chars  {time.time() - t0:6.2f}s",
                file=sys.stderr,
            )

    for n in sizes:
        t, m = tuned_params(n, tile, kchunk) if tune else (tile, kchunk)
        for variant in variants:
            emit(variant, n, t, m)
    if with_ablations and "staged" in variants:
        # k-chunk sweep at the paper-faithful tile=32 (E8); also emit the
        # paper's exact 32/8 configuration for tuned builds
        for m in ABLATION_KCHUNKS:
            emit("staged", ABLATION_SIZE, 32, m, tag=f"_t32m{m}")
        if tune:
            emit("staged", ABLATION_SIZE, 32, 8, tag="_t32m8")

    manifest = {
        "version": MANIFEST_VERSION,
        "tile": tile,
        "kchunk": kchunk,
        "jax_version": jax.__version__,
        "artifacts": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", type=pathlib.Path)
    ap.add_argument("--sizes", type=int, nargs="*", default=list(DEFAULT_SIZES))
    ap.add_argument("--variants", nargs="*", default=list(VARIANTS))
    ap.add_argument("--tile", type=int, default=DEFAULT_TILE)
    ap.add_argument("--kchunk", type=int, default=DEFAULT_KCHUNK)
    ap.add_argument("--no-ablations", action="store_true")
    ap.add_argument(
        "--no-tune",
        action="store_true",
        help="lower every size at the paper's exact tile/kchunk instead of "
        "the per-size tuned parameters (see tuned_params)",
    )
    args = ap.parse_args()

    for v in args.variants:
        if v not in VARIANTS:
            ap.error(f"unknown variant {v!r}; choose from {VARIANTS}")
    manifest = build(
        args.out_dir,
        tuple(args.sizes),
        tuple(args.variants),
        args.tile,
        args.kchunk,
        with_ablations=not args.no_ablations,
        tune=not args.no_tune,
    )
    print(
        f"wrote {len(manifest['artifacts'])} artifacts + manifest.json to {args.out_dir}",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()

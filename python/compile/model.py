"""L2: the blocked Floyd-Warshall computation graph (paper Fig. 2 on CUDA →
stage loop over Pallas phase kernels).

``apsp(w, variant=...)`` is the function the AOT path lowers: a
``lax.fori_loop`` over the n/s stages, each stage slicing out the diagonal
tile and the two panels (static shapes, dynamic offsets), running the three
phase kernels, and writing the results back.  The stage index is a traced
scalar — the slicing happens *outside* the Pallas calls so every
``pallas_call`` sees static shapes and static BlockSpecs.

Variants (= rows of the paper's Table 1 that run on the device):

  ``naive``    Harish & Narayanan: k-sequential full-matrix relaxation.
  ``blocked``  Katz & Kider: blocked, monolithic phase-3 kernel.
  ``staged``   this paper: blocked with the multi-stage phase-3 kernel.

Python in this module runs at build time only; the lowered HLO is what the
Rust runtime executes.
"""

from __future__ import annotations

import functools

import jax

from compile.kernels import (
    naive_jnp,
    phase1,
    phase2_col,
    phase2_row,
    phase3_monolithic,
    phase3_staged,
)

VARIANTS = ("naive", "blocked", "staged")
DEFAULT_TILE = 32
DEFAULT_KCHUNK = 8


def _stage_body(b, w, *, n: int, s: int, m: int, variant: str, interpret: bool):
    """One stage of blocked FW: phases 1, 2 (row+col), 3."""
    ks = b * s
    # Phase 1: close the independent (diagonal) block.
    diag = jax.lax.dynamic_slice(w, (ks, ks), (s, s))
    diag = phase1(diag, interpret=interpret)
    w = jax.lax.dynamic_update_slice(w, diag, (ks, ks))
    # Phase 2: singly-dependent panels (sequential k against the final diag).
    rowp = jax.lax.dynamic_slice(w, (ks, 0), (s, n))
    rowp = phase2_row(diag, rowp, interpret=interpret)
    w = jax.lax.dynamic_update_slice(w, rowp, (ks, 0))
    colp = jax.lax.dynamic_slice(w, (0, ks), (n, s))
    colp = phase2_col(diag, colp, interpret=interpret)
    w = jax.lax.dynamic_update_slice(w, colp, (0, ks))
    # Phase 3: doubly-dependent relaxation over the whole matrix (re-relaxing
    # the final panels is a no-op — DESIGN.md "Algorithm correctness note").
    if variant == "staged":
        w = phase3_staged(w, colp, rowp, s=s, m=m, interpret=interpret)
    else:
        w = phase3_monolithic(w, colp, rowp, s=s, interpret=interpret)
    return w


@functools.partial(
    jax.jit, static_argnames=("variant", "tile", "kchunk", "interpret")
)
def apsp(
    w: jax.Array,
    *,
    variant: str = "staged",
    tile: int = DEFAULT_TILE,
    kchunk: int = DEFAULT_KCHUNK,
    interpret: bool = True,
) -> jax.Array:
    """All-pairs shortest paths over a dense (n, n) f32 distance matrix.

    Input convention (matches the Rust side): ``w[i][i] == 0``, missing edges
    are ``+inf``.  ``n`` must be a multiple of ``tile`` (the Rust coordinator
    pads with unreachable vertices).
    """
    n = w.shape[0]
    assert w.shape == (n, n), f"square matrix required, got {w.shape}"
    if variant == "naive":
        return naive_jnp(w)
    assert variant in VARIANTS, f"unknown variant {variant!r}"
    assert n % tile == 0, f"n={n} not a multiple of tile={tile}"
    body = functools.partial(
        _stage_body, n=n, s=tile, m=kchunk, variant=variant, interpret=interpret
    )
    return jax.lax.fori_loop(0, n // tile, body, w)


def apsp_fn(variant: str, n: int, tile: int = DEFAULT_TILE, kchunk: int = DEFAULT_KCHUNK):
    """Return a single-argument jittable ``f(w) -> (dist,)`` for AOT lowering.

    The 1-tuple return matches the rust loader's ``to_tuple1()`` unwrap
    (HLO text is lowered with ``return_tuple=True``).
    """

    def fn(w):
        return (apsp(w, variant=variant, tile=tile, kchunk=kchunk),)

    fn.__name__ = f"apsp_{variant}_{n}"
    return fn

"""L2 model tests: the full blocked-FW composition vs the oracle, across
variants, tile sizes, k-chunks, and adversarial weight patterns."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import VARIANTS, apsp, apsp_fn
from tests.conftest import gold, make_matrix


class TestVariantsMatchOracle:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("n", [32, 64, 128])
    def test_random_graphs(self, variant, n):
        w = make_matrix(n, seed=n * 3)
        out = np.asarray(apsp(jnp.asarray(w), variant=variant))
        np.testing.assert_allclose(out, gold(w), rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_larger_probe(self, variant):
        w = make_matrix(256, seed=99, density=0.2)
        out = np.asarray(apsp(jnp.asarray(w), variant=variant))
        np.testing.assert_allclose(out, gold(w), rtol=1e-6, atol=1e-6)

    def test_variants_agree(self):
        w = jnp.asarray(make_matrix(128, seed=5))
        naive, blocked, staged = (np.asarray(apsp(w, variant=v)) for v in VARIANTS)
        # blocked and staged relax the same (i,k,j) sums, only the min order
        # differs — min reordering is exact on floats, so bitwise equal
        np.testing.assert_array_equal(blocked, staged)
        # naive relaxes through different intermediate values (per-k global
        # updates), so sums round differently: allclose, not bitwise
        np.testing.assert_allclose(naive, blocked, rtol=1e-5, atol=1e-6)


class TestTileAndChunkParameters:
    @pytest.mark.parametrize("tile", [16, 32, 64])
    def test_blocked_tile_sizes(self, tile):
        w = make_matrix(128, seed=tile)
        out = np.asarray(apsp(jnp.asarray(w), variant="blocked", tile=tile))
        np.testing.assert_allclose(out, gold(w), rtol=1e-6)

    @pytest.mark.parametrize("tile,kchunk", [(16, 4), (32, 4), (32, 8), (32, 16), (32, 32), (64, 8)])
    def test_staged_chunkings(self, tile, kchunk):
        w = make_matrix(128, seed=tile + kchunk)
        out = np.asarray(apsp(jnp.asarray(w), variant="staged", tile=tile, kchunk=kchunk))
        np.testing.assert_allclose(out, gold(w), rtol=1e-6)

    def test_single_block_matrix(self):
        # n == tile: one stage, no doubly-dependent blocks at all
        w = make_matrix(32, seed=0)
        out = np.asarray(apsp(jnp.asarray(w), variant="staged", tile=32))
        np.testing.assert_allclose(out, gold(w), rtol=1e-6)

    def test_rejects_non_multiple(self):
        w = jnp.zeros((48, 48), dtype=jnp.float32)
        with pytest.raises(AssertionError):
            apsp(w, variant="staged", tile=32)


class TestStructuredGraphs:
    def _run_all(self, w: np.ndarray):
        g = gold(w)
        for v in VARIANTS:
            np.testing.assert_allclose(
                np.asarray(apsp(jnp.asarray(w), variant=v)), g, rtol=1e-6, atol=1e-6
            ), v

    def test_ring(self):
        n = 64
        w = np.full((n, n), np.inf, dtype=np.float32)
        np.fill_diagonal(w, 0.0)
        for i in range(n):
            w[i, (i + 1) % n] = 1.0
        self._run_all(w)

    def test_star(self):
        n = 64
        w = np.full((n, n), np.inf, dtype=np.float32)
        np.fill_diagonal(w, 0.0)
        w[0, 1:] = 2.0
        w[1:, 0] = 3.0
        self._run_all(w)

    def test_two_components(self):
        n = 64
        w = make_matrix(n, seed=8, density=0.5)
        w[: n // 2, n // 2 :] = np.inf
        w[n // 2 :, : n // 2] = np.inf
        out = np.asarray(apsp(jnp.asarray(w), variant="staged"))
        assert np.isinf(out[: n // 2, n // 2 :]).all()
        assert np.isinf(out[n // 2 :, : n // 2]).all()
        np.testing.assert_allclose(out, gold(w), rtol=1e-6)

    def test_padded_matrix_unaffected(self):
        # padding convention of the Rust coordinator: extra unreachable
        # vertices (inf rows/cols, 0 diag) must not change real distances
        n, pad = 48, 64
        w = make_matrix(n, seed=12)
        wp = np.full((pad, pad), np.inf, dtype=np.float32)
        np.fill_diagonal(wp, 0.0)
        wp[:n, :n] = w
        out = np.asarray(apsp(jnp.asarray(wp), variant="staged"))
        np.testing.assert_allclose(out[:n, :n], gold(w), rtol=1e-6)
        assert np.isinf(out[n:, :n]).all() and np.isinf(out[:n, n:]).all()

    def test_negative_weights_dag(self):
        n = 64
        w = np.full((n, n), np.inf, dtype=np.float32)
        np.fill_diagonal(w, 0.0)
        rng = np.random.default_rng(4)
        for i in range(n):
            for j in range(i + 1, n):
                if rng.random() < 0.2:
                    w[i, j] = rng.uniform(-5.0, 5.0)  # forward edges only: no cycles
        self._run_all(w)


class TestFixpoint:
    @pytest.mark.parametrize("variant", VARIANTS)
    def test_idempotent(self, variant):
        # approximate under f32 (see test_ref.TestFixpointProperties)
        w = jnp.asarray(make_matrix(64, seed=21))
        once = np.asarray(apsp(w, variant=variant))
        twice = np.asarray(apsp(jnp.asarray(once), variant=variant))
        assert (twice <= once).all()
        np.testing.assert_allclose(twice, once, rtol=1e-6)

    def test_triangle_inequality(self):
        d = np.asarray(apsp(jnp.asarray(make_matrix(96, seed=33)), variant="staged"))
        viol = d[:, None, :] > (d[:, :, None] + d[None, :, :]) + 1e-4
        assert not viol.any()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.05, 0.9))
    def test_hypothesis_staged_vs_oracle(self, seed, density):
        w = make_matrix(64, seed=seed, density=density)
        out = np.asarray(apsp(jnp.asarray(w), variant="staged"))
        np.testing.assert_allclose(out, gold(w), rtol=1e-6, atol=1e-6)


class TestAotFn:
    def test_apsp_fn_returns_tuple(self):
        w = jnp.asarray(make_matrix(32, seed=2))
        fn = apsp_fn("staged", 32)
        out = fn(w)
        assert isinstance(out, tuple) and len(out) == 1
        np.testing.assert_allclose(
            np.asarray(out[0]), gold(np.asarray(w)), rtol=1e-6
        )

    def test_apsp_fn_name(self):
        assert apsp_fn("blocked", 128).__name__ == "apsp_blocked_128"

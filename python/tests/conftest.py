"""Shared fixtures for the kernel/model test suite."""

from __future__ import annotations

import numpy as np
import pytest

from compile.kernels import ref


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xF10D)


def make_matrix(n: int, seed: int = 0, density: float = 0.4) -> np.ndarray:
    """Convenience wrapper returning a numpy f32 distance matrix."""
    # np.array (not asarray): jax arrays view as read-only; tests mutate
    return np.array(ref.random_distance_matrix(n, seed=seed, density=density))


def gold(w: np.ndarray) -> np.ndarray:
    """Ground-truth APSP via the numpy oracle."""
    return ref.floyd_warshall_numpy(w)

"""L1 §Perf: structural verification of the staged kernel's memory plan.

Interpret-mode wallclock is not a TPU proxy (DESIGN.md §Perf), so the L1
performance deliverable is *structural*: the lowered HLO must implement the
paper's staged schedule — per k-step, only an (s, m) slice of the column
panel and an (m, s) slice of the row panel are resident (the VMEM analog of
the paper's 2·t·m shared-memory words), while the output tile persists
across the k grid (the register-resident tile of §4.1).

These tests lower the kernels and assert those shapes/loops exist in the
HLO, and re-derive the paper's §3.3/§4.2 occupancy arithmetic exactly.
"""

from __future__ import annotations

import re

import jax
import pytest

from compile import aot
from compile.kernels import ref
from compile.model import apsp


def hlo_for(variant: str, n: int, tile: int, kchunk: int) -> str:
    fn = lambda w: (apsp(w, variant=variant, tile=tile, kchunk=kchunk),)
    spec = jax.ShapeDtypeStruct((n, n), jax.numpy.float32)
    return aot.to_hlo_text(jax.jit(fn).lower(spec))


class TestStagedSchedule:
    N, S, M = 128, 32, 8

    @pytest.fixture(scope="class")
    def staged_hlo(self):
        return hlo_for("staged", self.N, self.S, self.M)

    @pytest.fixture(scope="class")
    def blocked_hlo(self):
        return hlo_for("blocked", self.N, self.S, self.M)

    def test_staged_streams_panel_slices(self, staged_hlo):
        # the staged phase-3 body must move (s, m) and (m, s) panel slices —
        # the 2·t·m-word resident set of paper §4.2
        assert f"f32[{self.S},{self.M}]" in staged_hlo, "(s, m) column-panel slice missing"
        assert f"f32[{self.M},{self.S}]" in staged_hlo, "(m, s) row-panel slice missing"

    def test_monolithic_keeps_full_tiles(self, blocked_hlo):
        # Katz–Kider analog: full (s, s) panel tiles resident, no (s, m) slices
        assert f"f32[{self.S},{self.S}]" in blocked_hlo
        assert f"f32[{self.S},{self.M}]" not in blocked_hlo

    def test_both_lower_to_loops_not_unrolled(self, staged_hlo, blocked_hlo):
        # grid → while loops; full unrolling would explode artifact size
        assert staged_hlo.count("while") >= 2
        assert blocked_hlo.count("while") >= 2
        assert len(staged_hlo) < 200_000

    def test_staged_grid_has_k_dimension(self, staged_hlo, blocked_hlo):
        # the staged kernel adds the k grid dimension: its innermost loop
        # count (s/m more steps) shows up as a larger loop-bound constant in
        # at least one while condition. Compare total dynamic-slice count as
        # a proxy: staged slices panels per k-step.
        staged_slices = len(re.findall(r"dynamic-slice", staged_hlo))
        blocked_slices = len(re.findall(r"dynamic-slice", blocked_hlo))
        assert staged_slices >= blocked_slices, (staged_slices, blocked_slices)


class TestFootprintArithmetic:
    """The paper's own numbers, §3.3 / §4.1 / §4.2, re-derived exactly."""

    def test_katz_kider_shared_memory(self):
        # 3 tiles × 32² words × 4 B + 32 B parameters = 12320 B
        assert 3 * 32 * 32 * 4 + 32 == 12320

    def test_registers_variant_shared_memory(self):
        # 2 tiles in smem (out tile moved to registers) = 8224 B
        assert 2 * 32 * 32 * 4 + 32 == 8224

    def test_staged_shared_memory(self):
        # 2 slices × 32 × 4 words × 4 B + 32 B = 1056 B (§4.2)
        assert 2 * 32 * 4 * 4 + 32 == 1056

    def test_factor_12_reduction(self):
        # "reduce the shared memory used by a thread block by a factor of
        # nearly 12"
        assert 11 < 12320 / 1056 < 12

    def test_vmem_resident_panel_ratio(self):
        # TPU analog: resident panel words drop t/m = 4× per step
        t, m = 32, 8
        assert (2 * t * t) / (2 * t * m) == t / m == 4

    def test_register_tile_per_thread(self):
        # §4.1: t·t/h elements per thread with h=64 threads → 16 registers
        assert 32 * 32 // 64 == 16


class TestTunedParams:
    def test_tuning_keeps_four_stages(self):
        # the tuned artifacts preserve the paper's 4-stage structure m = t/4
        for n in (64, 128, 256, 512, 4096):
            t, m = aot.tuned_params(n, 32, 8)
            assert t % m == 0 and t // m == 4, (n, t, m)

    def test_tuning_bounds(self):
        for n in (64, 128, 256, 512, 4096):
            t, m = aot.tuned_params(n, 32, 8)
            assert 32 <= t <= 128 and t <= n
            assert n % t == 0, f"tile {t} must divide n {n}"

    def test_tuned_matches_reference(self):
        # correctness is tile-independent: tuned params give oracle results
        import numpy as np

        n = 128
        w = ref.random_distance_matrix(n, seed=5)
        t, m = aot.tuned_params(n, 32, 8)
        out = apsp(w, variant="staged", tile=t, kchunk=m)
        np.testing.assert_allclose(
            np.asarray(out), ref.floyd_warshall_numpy(np.asarray(w)), rtol=1e-6
        )

"""Pallas phase kernels vs the pure-jnp reference recurrences.

These are the core L1 correctness tests: every kernel is checked against the
matching ``ref`` function over deterministic sizes and hypothesis-driven
random sweeps (shapes, seeds, densities, negative weights).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    naive_jnp,
    naive_pallas,
    phase1,
    phase2_col,
    phase2_row,
    phase3_monolithic,
    phase3_staged,
    ref,
)
from tests.conftest import gold, make_matrix


def _tile(n: int, seed: int, density: float = 0.5) -> jnp.ndarray:
    return jnp.asarray(make_matrix(n, seed=seed, density=density))


class TestPhase1:
    @pytest.mark.parametrize("s", [8, 16, 32, 64])
    def test_matches_ref(self, s):
        t = _tile(s, seed=s)
        np.testing.assert_allclose(
            np.asarray(phase1(t)), np.asarray(ref.fw_tile_inplace(t)), rtol=1e-6
        )

    def test_is_full_fw_on_tile(self):
        # phase1 on an (s,s) tile IS the complete APSP of that subgraph
        t = _tile(32, seed=1)
        np.testing.assert_allclose(np.asarray(phase1(t)), gold(np.asarray(t)), rtol=1e-6)

    def test_idempotent(self):
        # approximate under f32 (see test_ref.TestFixpointProperties)
        t = phase1(_tile(32, seed=2))
        again = np.asarray(phase1(t))
        assert (again <= np.asarray(t)).all()
        np.testing.assert_allclose(again, np.asarray(t), rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.05, 1.0))
    def test_hypothesis_sweep(self, seed, density):
        t = _tile(16, seed=seed, density=density)
        np.testing.assert_allclose(
            np.asarray(phase1(t)), gold(np.asarray(t)), rtol=1e-6
        )


class TestPhase2:
    @pytest.mark.parametrize("s,n", [(16, 64), (32, 128), (32, 32)])
    def test_row_matches_ref(self, s, n):
        diag = phase1(_tile(s, seed=s))
        panel = jnp.asarray(make_matrix(n, seed=n)[:s, :])
        np.testing.assert_allclose(
            np.asarray(phase2_row(diag, panel)),
            np.asarray(ref.fw_row_panel(diag, panel)),
            rtol=1e-6,
        )

    @pytest.mark.parametrize("s,n", [(16, 64), (32, 128), (32, 32)])
    def test_col_matches_ref(self, s, n):
        diag = phase1(_tile(s, seed=s + 1))
        panel = jnp.asarray(make_matrix(n, seed=n + 1)[:, :s])
        np.testing.assert_allclose(
            np.asarray(phase2_col(diag, panel)),
            np.asarray(ref.fw_col_panel(diag, panel)),
            rtol=1e-6,
        )

    def test_row_panel_tiles_independent(self):
        # permuting which grid tile holds which columns must not interact:
        # process two disjoint panels separately == as one wide panel
        s, n = 16, 64
        diag = phase1(_tile(s, seed=7))
        panel = jnp.asarray(make_matrix(n, seed=8)[:s, :])
        whole = np.asarray(phase2_row(diag, panel))
        left = np.asarray(phase2_row(diag, panel[:, : n // 2]))
        right = np.asarray(phase2_row(diag, panel[:, n // 2 :]))
        np.testing.assert_array_equal(whole, np.concatenate([left, right], axis=1))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_row_col(self, seed):
        s, n = 16, 48
        diag = phase1(_tile(s, seed=seed))
        rowp = jnp.asarray(make_matrix(n, seed=seed + 1)[:s, :])
        colp = jnp.asarray(make_matrix(n, seed=seed + 2)[:, :s])
        np.testing.assert_allclose(
            np.asarray(phase2_row(diag, rowp)),
            np.asarray(ref.fw_row_panel(diag, rowp)),
            rtol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(phase2_col(diag, colp)),
            np.asarray(ref.fw_col_panel(diag, colp)),
            rtol=1e-6,
        )


class TestPhase3:
    def _setup(self, n, s, seed):
        w = _tile(n, seed=seed)
        colp = jnp.asarray(make_matrix(n, seed=seed + 1)[:, :s])
        rowp = jnp.asarray(make_matrix(n, seed=seed + 2)[:s, :])
        expect = jnp.minimum(w, ref.min_plus_matmul(colp, rowp))
        return w, colp, rowp, np.asarray(expect)

    @pytest.mark.parametrize("n,s", [(64, 16), (64, 32), (128, 32), (32, 32)])
    def test_monolithic_matches_ref(self, n, s):
        w, colp, rowp, expect = self._setup(n, s, seed=n + s)
        np.testing.assert_allclose(
            np.asarray(phase3_monolithic(w, colp, rowp, s=s)), expect, rtol=1e-6
        )

    @pytest.mark.parametrize(
        "n,s,m", [(64, 16, 4), (64, 32, 8), (128, 32, 8), (64, 32, 32), (64, 32, 4)]
    )
    def test_staged_matches_ref(self, n, s, m):
        w, colp, rowp, expect = self._setup(n, s, seed=n + s + m)
        np.testing.assert_allclose(
            np.asarray(phase3_staged(w, colp, rowp, s=s, m=m)), expect, rtol=1e-6
        )

    def test_staged_equals_monolithic_all_chunks(self):
        # the paper's staging claim: k-chunking must not change results
        n, s = 64, 32
        w, colp, rowp, _ = self._setup(n, s, seed=42)
        mono = np.asarray(phase3_monolithic(w, colp, rowp, s=s))
        for m in (1, 2, 4, 8, 16, 32):
            staged = np.asarray(phase3_staged(w, colp, rowp, s=s, m=m))
            np.testing.assert_array_equal(staged, mono), f"m={m}"

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        m=st.sampled_from([2, 4, 8, 16]),
        density=st.floats(0.05, 1.0),
    )
    def test_hypothesis_staged(self, seed, m, density):
        n, s = 32, 16
        w = _tile(n, seed=seed, density=density)
        colp = jnp.asarray(make_matrix(n, seed=seed + 1, density=density)[:, :s])
        rowp = jnp.asarray(make_matrix(n, seed=seed + 2, density=density)[:s, :])
        expect = np.asarray(jnp.minimum(w, ref.min_plus_matmul(colp, rowp)))
        np.testing.assert_allclose(
            np.asarray(phase3_staged(w, colp, rowp, s=s, m=m)), expect, rtol=1e-6
        )


class TestNaive:
    @pytest.mark.parametrize("n", [16, 64, 128])
    def test_jnp_matches_oracle(self, n):
        w = _tile(n, seed=n)
        np.testing.assert_allclose(
            np.asarray(naive_jnp(w)), gold(np.asarray(w)), rtol=1e-6
        )

    @pytest.mark.parametrize("n", [16, 64])
    def test_pallas_matches_oracle(self, n):
        w = _tile(n, seed=n + 1)
        np.testing.assert_allclose(
            np.asarray(naive_pallas(w)), gold(np.asarray(w)), rtol=1e-6
        )

    def test_pallas_matches_jnp_exactly(self):
        w = _tile(64, seed=3)
        np.testing.assert_array_equal(np.asarray(naive_pallas(w)), np.asarray(naive_jnp(w)))


class TestInfinityAndEdgeCases:
    def test_all_inf_offdiag(self):
        n = 32
        w = jnp.full((n, n), jnp.inf, dtype=jnp.float32)
        w = w.at[jnp.arange(n), jnp.arange(n)].set(0.0)
        out = np.asarray(phase1(w))
        np.testing.assert_array_equal(out, np.asarray(w))

    def test_inf_plus_inf_no_nan(self):
        # inf + inf must stay inf (never NaN) through the min-plus kernels
        n, s = 32, 16
        w = jnp.full((n, n), jnp.inf, dtype=jnp.float32)
        colp = jnp.full((n, s), jnp.inf, dtype=jnp.float32)
        rowp = jnp.full((s, n), jnp.inf, dtype=jnp.float32)
        out = np.asarray(phase3_staged(w, colp, rowp, s=s, m=4))
        assert np.isinf(out).all() and not np.isnan(out).any()

    def test_negative_weights(self):
        n = 32
        w = make_matrix(n, seed=77)
        # shift finite off-diagonal weights negative but keep diag 0 and no
        # negative cycles (upper-triangular negativity only → DAG-like)
        neg = w.copy()
        iu = np.triu_indices(n, 1)
        finite = np.isfinite(neg[iu])
        neg[iu] = np.where(finite, neg[iu] - 5.0, neg[iu])
        out = np.asarray(phase1(jnp.asarray(neg[:32, :32])))
        np.testing.assert_allclose(out, gold(neg[:32, :32]), rtol=1e-5)

    def test_zero_weight_edges(self):
        n = 16
        w = np.zeros((n, n), dtype=np.float32)
        out = np.asarray(phase1(jnp.asarray(w)))
        np.testing.assert_array_equal(out, w)

"""Oracle self-consistency: the reference implementations must agree with
each other and with hand-computed small cases before they are trusted to
judge the Pallas kernels."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from tests.conftest import gold, make_matrix

INF = np.float32(np.inf)


class TestFloydWarshallSmall:
    def test_two_node_line(self):
        w = np.array([[0.0, 3.0], [INF, 0.0]], dtype=np.float32)
        out = gold(w)
        np.testing.assert_array_equal(out, w)  # already shortest

    def test_triangle_shortcut(self):
        # 0->1 direct is 10, via 2 is 2+3=5
        w = np.array(
            [[0.0, 10.0, 2.0], [INF, 0.0, INF], [INF, 3.0, 0.0]],
            dtype=np.float32,
        )
        out = gold(w)
        assert out[0, 1] == 5.0
        assert out[0, 2] == 2.0
        assert out[2, 1] == 3.0

    def test_disconnected_stays_inf(self):
        w = np.full((4, 4), INF, dtype=np.float32)
        np.fill_diagonal(w, 0.0)
        w[0, 1] = 1.0
        out = gold(w)
        assert out[0, 1] == 1.0
        assert np.isinf(out[1, 0])
        assert np.isinf(out[2, 3])

    def test_negative_edges_no_cycle(self):
        # negative edge allowed as long as no negative cycle
        w = np.array(
            [[0.0, -2.0, INF], [INF, 0.0, 4.0], [1.0, INF, 0.0]],
            dtype=np.float32,
        )
        out = gold(w)
        assert out[0, 2] == 2.0  # 0->1->2 = -2+4
        assert out[2, 1] == -1.0  # 2->0->1 = 1-2

    def test_path_through_chain(self):
        n = 8
        w = np.full((n, n), INF, dtype=np.float32)
        np.fill_diagonal(w, 0.0)
        for i in range(n - 1):
            w[i, i + 1] = 1.0
        out = gold(w)
        for i in range(n):
            for j in range(i, n):
                assert out[i, j] == j - i


class TestOracleAgreement:
    @pytest.mark.parametrize("n", [16, 32, 64, 96])
    def test_jnp_matches_numpy(self, n):
        w = make_matrix(n, seed=n)
        np.testing.assert_allclose(
            np.asarray(ref.floyd_warshall(jnp.asarray(w))), gold(w), rtol=1e-6
        )

    @pytest.mark.parametrize("n,s", [(32, 16), (64, 16), (64, 32), (96, 32), (128, 32)])
    def test_blocked_matches_numpy(self, n, s):
        w = make_matrix(n, seed=n + s)
        np.testing.assert_allclose(
            np.asarray(ref.blocked_floyd_warshall(jnp.asarray(w), s)),
            gold(w),
            rtol=1e-6,
        )

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        density=st.floats(0.05, 1.0),
        nb=st.integers(1, 4),
    )
    def test_blocked_matches_numpy_hypothesis(self, seed, density, nb):
        n = 16 * nb
        w = make_matrix(n, seed=seed, density=density)
        np.testing.assert_allclose(
            np.asarray(ref.blocked_floyd_warshall(jnp.asarray(w), 16)),
            gold(w),
            rtol=1e-6,
        )


class TestMinPlus:
    def test_identity(self):
        # min-plus identity: diag 0, off-diag inf
        n = 8
        ident = np.full((n, n), INF, dtype=np.float32)
        np.fill_diagonal(ident, 0.0)
        a = make_matrix(n, seed=3)
        np.testing.assert_array_equal(
            np.asarray(ref.min_plus_matmul(jnp.asarray(a), jnp.asarray(ident))), a
        )
        np.testing.assert_array_equal(
            np.asarray(ref.min_plus_matmul(jnp.asarray(ident), jnp.asarray(a))), a
        )

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_associative(self, seed):
        n = 12
        a = jnp.asarray(make_matrix(n, seed=seed))
        b = jnp.asarray(make_matrix(n, seed=seed + 1))
        c = jnp.asarray(make_matrix(n, seed=seed + 2))
        left = ref.min_plus_matmul(ref.min_plus_matmul(a, b), c)
        right = ref.min_plus_matmul(a, ref.min_plus_matmul(b, c))
        np.testing.assert_allclose(np.asarray(left), np.asarray(right), rtol=1e-5)

    def test_matrix_power_equals_fw(self):
        # (min,+) closure: repeated squaring of (W) reaches the APSP fixpoint
        n = 16
        w = jnp.asarray(make_matrix(n, seed=9))
        sq = w
        for _ in range(4):  # log2(16) squarings
            sq = jnp.minimum(sq, ref.min_plus_matmul(sq, sq))
        np.testing.assert_allclose(np.asarray(sq), gold(np.asarray(w)), rtol=1e-6)


class TestFixpointProperties:
    @pytest.mark.parametrize("n", [16, 48])
    def test_idempotent(self, n):
        # f32 note: re-relaxation may lower a value by ~1 ulp (the stored min
        # was rounded through a different association), so idempotence is
        # approximate — but strictly monotone non-increasing.
        w = gold(make_matrix(n, seed=n * 7))
        again = gold(w)
        assert (again <= w).all()
        np.testing.assert_allclose(again, w, rtol=1e-6)

    @pytest.mark.parametrize("n", [16, 48])
    def test_triangle_inequality(self, n):
        d = gold(make_matrix(n, seed=n * 11))
        # d[i,j] <= d[i,k] + d[k,j] for all i,j,k
        viol = d[:, None, :] > (d[:, :, None] + d[None, :, :]) + 1e-4
        assert not viol.any()

    def test_result_never_exceeds_input(self):
        w = make_matrix(32, seed=5)
        assert (gold(w) <= w + 1e-6).all()


class TestRandomMatrix:
    def test_shape_and_diag(self):
        w = make_matrix(64, seed=1)
        assert w.shape == (64, 64)
        assert w.dtype == np.float32
        np.testing.assert_array_equal(np.diag(w), np.zeros(64, dtype=np.float32))

    def test_density_controls_inf_fraction(self):
        dense = make_matrix(128, seed=2, density=0.9)
        sparse = make_matrix(128, seed=2, density=0.1)
        assert np.isinf(sparse).sum() > np.isinf(dense).sum()

    def test_deterministic_by_seed(self):
        np.testing.assert_array_equal(make_matrix(32, seed=4), make_matrix(32, seed=4))
        assert not np.array_equal(make_matrix(32, seed=4), make_matrix(32, seed=5))

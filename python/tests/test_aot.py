"""AOT pipeline tests: lowering produces parseable HLO text and a manifest
the Rust side can trust."""

from __future__ import annotations

import json
import pathlib

import pytest

from compile import aot
from compile.model import VARIANTS


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(
        out,
        sizes=(64,),
        variants=VARIANTS,
        tile=32,
        kchunk=8,
        with_ablations=False,
        verbose=False,
    )
    return out, manifest


class TestLowering:
    def test_hlo_text_is_hlo(self, built):
        out, manifest = built
        for e in manifest["artifacts"]:
            text = (out / e["name"]).read_text()
            assert text.startswith("HloModule"), e["name"]
            # the entry computation takes one f32[n,n] parameter
            assert f"f32[{e['n']},{e['n']}]" in text

    def test_every_variant_emitted(self, built):
        _, manifest = built
        assert {e["variant"] for e in manifest["artifacts"]} == set(VARIANTS)

    def test_staged_and_blocked_contain_loops(self, built):
        # blocked/staged lower the pallas grid to HLO while loops —
        # guard against accidental full unrolling (artifact-size blowup)
        out, manifest = built
        for e in manifest["artifacts"]:
            if e["variant"] in ("blocked", "staged"):
                assert "while" in (out / e["name"]).read_text()

    def test_deterministic(self, built, tmp_path):
        out, manifest = built
        again = aot.build(
            tmp_path, sizes=(64,), variants=("staged",), tile=32, kchunk=8,
            with_ablations=False, verbose=False,
        )
        (first,) = [e for e in manifest["artifacts"] if e["variant"] == "staged"]
        (second,) = again["artifacts"]
        assert first["sha256"] == second["sha256"]


class TestManifest:
    def test_schema(self, built):
        out, manifest = built
        assert manifest["version"] == aot.MANIFEST_VERSION
        assert manifest["tile"] == 32
        for e in manifest["artifacts"]:
            assert e["dtype"] == "f32"
            assert e["input_shape"] == [e["n"], e["n"]]
            assert e["output_shape"] == [e["n"], e["n"]]
            assert (out / e["name"]).stat().st_size == e["bytes"]

    def test_manifest_written_to_disk(self, built):
        out, manifest = built
        on_disk = json.loads((out / "manifest.json").read_text())
        assert on_disk == manifest

    def test_kchunk_only_for_staged(self, built):
        _, manifest = built
        for e in manifest["artifacts"]:
            if e["variant"] == "staged":
                assert e["kchunk"] == 8
            else:
                assert e["kchunk"] is None

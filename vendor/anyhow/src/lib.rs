//! Vendored subset of the `anyhow` error-handling API.
//!
//! This build runs fully offline against a vendored crate set (see the
//! repository's DESIGN.md §Substitutions), so the crates.io `anyhow` is
//! replaced by this first-party implementation of the surface the codebase
//! uses:
//!
//! * [`Error`] — an opaque error value carrying a context chain;
//! * [`Result`] — `Result<T, Error>` with a defaulted error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros;
//! * a blanket `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Formatting matches upstream where it matters to callers: `{}` prints the
//! outermost message, `{:#}` prints the whole chain joined by `": "`, and
//! `{:?}` prints the message plus a `Caused by:` list (what `.unwrap()` and
//! `.expect(..)` show).  Downcasting and backtraces are deliberately out of
//! scope — nothing in this repository uses them.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: an outermost message plus the chain of causes beneath
/// it.  Source errors are flattened to their rendered messages at capture
/// time, which keeps the type `Send + Sync + 'static` for free.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Construct from a std error, capturing its `source()` chain.
    pub fn from_std<E: StdError + ?Sized>(error: &E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message (what [`Context::context`] does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            if self.chain.len() == 2 {
                write!(f, "\n    {}", self.chain[1])?;
            } else {
                for (i, cause) in self.chain[1..].iter().enumerate() {
                    write!(f, "\n    {i}: {cause}")?;
                }
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`; that
// is what makes this blanket impl coherent (same trick as upstream anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::from_std(&error)
    }
}

/// Attach context to a fallible value.
pub trait Context<T> {
    /// Wrap the error with `context` (evaluated eagerly).
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with `f()` (evaluated only on the error path).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err::<(), std::io::Error>(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_chain_formats() {
        let result: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = result.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("file missing"), "{dbg}");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let v: Option<u8> = Some(3);
        assert_eq!(v.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert!(fails(12).unwrap_err().to_string().contains("x too big: 12"));
        assert!(fails(5).unwrap_err().to_string().contains("five"));
        let owned = String::from("already rendered");
        assert_eq!(anyhow!(owned).to_string(), "already rendered");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn check() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        let msg = check().unwrap_err().to_string();
        assert!(msg.contains("1 + 1 == 3"), "{msg}");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync + 'static>() {}
        assert_send_sync::<Error>();
    }
}

# fw-stage build orchestration.
#
# `artifacts` runs the build-time Python layer once (L1 Pallas kernels →
# L2 AOT HLO-text artifacts + manifest); Python never runs on the request
# path.  Artifacts land in rust/artifacts/ where the Rust tests, benches,
# and the fw-stage binary discover them.

ARTIFACT_DIR := rust/artifacts

.PHONY: artifacts clean-artifacts build test bench fmt

artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(ARTIFACT_DIR)

clean-artifacts:
	rm -rf $(ARTIFACT_DIR)

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --no-run

fmt:
	cargo fmt --check
